"""Collective helpers: plain and compressed cross-replica averaging.

`compressed_pmean` implements the int8+error-feedback averaging used at the
two MBProx sync points: quantize locally, average the dequantized values
(the all-reduce payload is 4x smaller on the wire under a quantized-
collective transport; with standard all-reduce the savings apply to the
eventual int8-transport runtimes and the EF guarantees hold either way).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim import compression as comp


def pmean_tree(tree, axis_name):
    return jax.tree.map(lambda x: lax.pmean(x, axis_name), tree)


def compressed_pmean(tree, ef: comp.EFState, axis_name):
    """int8 + error-feedback averaged tree. Returns (avg_tree, new_ef)."""
    compressed, new_ef = comp.quantize_int8(tree, ef)
    deq = comp.dequantize_int8(compressed)
    avg = jax.tree.map(lambda x: lax.pmean(x, axis_name), deq)
    return avg, new_ef


def wire_bytes(tree, compressed: bool = False) -> int:
    if compressed:
        return comp.compressed_bytes_int8(tree)
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
