"""Process-global sharding context for in-loop constraints.

GSPMD reshards scan inputs at the loop boundary: if a scanned-over stacked
weight needs gathering (FSDP), the all-gather of the WHOLE stack is hoisted
out of the while loop — a 12-48 GB temp for the big archs. Pinning the sliced
per-layer weights to their sharded spec INSIDE the loop body forces
partial-matmul + psum instead (2D tensor parallelism), keeping memory flat.

The launcher/dry-run sets the spec tree here before tracing; model code picks
it up inside the scan bodies. None (default) = no constraints (single-device
tests, examples).
"""
from __future__ import annotations

_INLOOP_SPECS = None   # {'p0': spec-tree-for-sliced-block-params, ...}
_ACT_SPEC = None       # PartitionSpec for (B, S, D) activations


def set_inloop_specs(specs) -> None:
    global _INLOOP_SPECS
    _INLOOP_SPECS = specs


def get_inloop_specs():
    return _INLOOP_SPECS


_MOE_GATHER_SPECS = None  # spec tree for gathered (data-unsharded) experts
_MOE_XE_SPEC = None       # sharding for routed expert inputs (g, E, C, D)


def set_moe_xe_spec(spec) -> None:
    global _MOE_XE_SPEC
    _MOE_XE_SPEC = spec


def get_moe_xe_spec():
    return _MOE_XE_SPEC


def set_moe_gather_specs(specs) -> None:
    """Pin MoE expert weights to their gathered (model-only) sharding at
    the moe_block entry — ONE FSDP all-gather per layer visit, hoisted out
    of the sequence-chunk loop (which would otherwise re-gather per chunk:
    measured 6.6 TB/step on grok-1)."""
    global _MOE_GATHER_SPECS
    _MOE_GATHER_SPECS = specs


def get_moe_gather_specs():
    return _MOE_GATHER_SPECS


def set_activation_spec(spec) -> None:
    """Pin (B, S, D) activations to batch-over-data inside every layer —
    without this, FSDP weight shardings (feature dims over 'data') win the
    GSPMD propagation fight and REPLICATE the batch (observed on grok-1:
    activations showed the full global batch per device)."""
    global _ACT_SPEC
    _ACT_SPEC = spec


def get_activation_spec():
    return _ACT_SPEC
