"""PartitionSpec rules per architecture: TP over 'model', optional FSDP over
'data', EP for divisible expert counts, batch over ('pod','data').

Rules are name-based over the param pytree paths produced by models/lm.py.
Stacked superblock leaves get a leading None. GSPMD uneven-sharding padding
covers head counts not divisible by the 16-way model axis (llama4 40H,
smollm 9H, recurrentgemma 10H, paligemma 8H) — see DESIGN.md §4.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Archs whose params+opt do not fit replicated over the data axis: FSDP.
FSDP_ARCHS = {"llama4-maverick-400b-a17b", "grok-1-314b"}


def needs_fsdp(cfg) -> bool:
    return cfg.name in FSDP_ARCHS


def _rule(path_names, leaf, cfg, fsdp: bool, model_axis="model",
          fsdp_axis="data"):
    """PartitionSpec for one leaf, EXCLUDING the stacked n_super axis."""
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""
    M, F = model_axis, (fsdp_axis if fsdp else None)

    if name == "embed":
        # vocab over model only — FSDP'ing D over 'data' lets the feature
        # sharding hijack the data axis from the batch (GSPMD propagation)
        if cfg.frontend == "audio":
            return P(None, M, None)
        return P(M, None)
    if name == "head":
        return P(None, M)
    if parent == "vision":
        return P(None, None)

    # attention projections
    if name in ("wq", "wk", "wv"):
        return P(F, M)
    if name == "wo":
        return P(M, F)

    # dense MLP
    if parent == "mlp" or parent == "cmix":
        if name in ("w_gate", "w_up", "w_k"):
            return P(F, M)
        if name in ("w_down", "w_v"):
            return P(M, F)
        if name == "w_r":
            return P(F, M)
        if name == "mix":
            return P(None, None)

    # MoE experts: EP over 'model' when the expert count divides (llama4),
    # else d_ff over 'model' (grok); FSDP storage over 'data' for the >10B
    # archs with an explicit ONCE-PER-LAYER gather hoisted out of the
    # sequence-chunk loop (models/moe.py; §Perf iterations 4-5 — sharding
    # d_ff over 'data' instead conflicts with batch-over-data and made
    # GSPMD all-gather the dispatch tensors: 15 TB/step).
    if parent == "moe":
        ep = cfg.n_experts % 16 == 0
        if name == "router":
            return P(None, None)
        if name in ("w_gate", "w_up"):
            return P(M, F, None) if ep else P(None, F, M)
        if name == "w_down":
            return P(M, None, F) if ep else P(None, M, F)

    # RWKV time-mix
    if parent == "tmix":
        if name in ("w_r", "w_k", "w_v", "w_g"):
            return P(F, M)
        if name == "w_o":
            return P(M, F)
        if name == "decay_A":
            return P(None, None)
        if name == "decay_B":
            return P(None, M)
        if name in ("decay_base", "ln_scale"):
            return P(M)
        if name == "bonus_u":
            return P(None, M)  # (H, hd): H often not 16-divisible; hd is
        if name == "mix":
            return P(None, None)

    # RG-LRU
    if parent == "rec":
        if name in ("w_in_rec", "w_in_gate"):
            return P(F, M)
        if name in ("w_a", "w_x"):
            return P(None, M)
        if name == "conv_w":
            return P(None, M)
        if name in ("conv_b", "b_a", "b_x", "log_lambda"):
            return P(M)
        if name == "w_out":
            return P(M, F)

    # norms, scalars, counters
    return P(*([None] * leaf.ndim))


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop axis names on dims they don't evenly divide (jit input shardings
    require exact divisibility; compute-internal shardings may still be
    uneven via GSPMD propagation). Tuple entries degrade to the longest
    dividing prefix (e.g. ('data','model') -> ('data',) for batch 128 on a
    16x16 mesh)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        while axes:
            ways = 1
            for a in axes:
                ways *= sizes[a]
            if i < len(shape) and shape[i] % ways == 0:
                break
            axes.pop()
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def sanitize_tree(spec_tree, shape_tree, mesh):
    return jax.tree.map(
        lambda sp, s: sanitize_spec(sp, s.shape, mesh), spec_tree,
        shape_tree, is_leaf=lambda x: isinstance(x, P))


def _path_names(path):
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(k.key)
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return names


def param_specs(params, cfg, fsdp: bool | None = None):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    fsdp = needs_fsdp(cfg) if fsdp is None else fsdp
    dp_only = getattr(cfg, "parallelism", "tp") == "dp_only"

    def spec_for(path, leaf):
        names = _path_names(path)
        stacked = "blocks" in names
        if dp_only:
            nd = leaf.ndim
            return P(*([None] * nd))
        base = _rule(names, _Unstacked(leaf, stacked), cfg, fsdp)
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(spec_for, params)


class _Unstacked:
    """Leaf view with the stacked n_super axis removed (rank bookkeeping)."""

    def __init__(self, leaf, stacked):
        self.ndim = leaf.ndim - (1 if stacked else 0)


def opt_state_specs(opt_state, param_spec_tree, cfg):
    """Optimizer state mirrors params (m/v/anchor leaves) + scalar counters."""
    def spec_for(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0:
            return P()
        # strip the optimizer-level prefix ('m','v','anchor','0'...) then
        # look up the matching param leaf path
        stacked = "blocks" in names
        base = _rule(names, _Unstacked(leaf, stacked), cfg,
                     fsdp=needs_fsdp(cfg))
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(spec_for, opt_state)


def batch_specs(batch, dp_axes: tuple, leading_micro: bool):
    """Shard the batch dim over data(+pod); microbatch axis (if any) first."""
    def spec_for(leaf):
        if leading_micro:
            return P(None, dp_axes)
        return P(dp_axes)
    return jax.tree.map(spec_for, batch)


def decode_state_specs(state, cfg, dp_axes: tuple):
    """KV caches / recurrent state: batch over data(+pod), KV heads over
    'model' when divisible (else replicated over model)."""
    # KV cache TP rule: shard KV heads over 'model' when divisible;
    # otherwise shard the SEQUENCE dim (FlashDecoding-style context
    # parallelism — softmax stats all-reduced, avoids the SPMD involuntary
    # replication seen with head_dim-sharded contractions).
    if cfg.n_kv_heads % 16 == 0:
        seq_axis, kv_axes = None, ("model", None)
    else:
        seq_axis, kv_axes = "model", (None, None)

    def spec_for(path, leaf):
        names = _path_names(path)
        stacked = "blocks" in names
        lead = (None,) if stacked else ()
        nd = leaf.ndim - len(lead)
        if names[-1] in ("k", "v"):
            # (B, S, KV, hd)
            return P(*lead, dp_axes, seq_axis, *kv_axes)
        if names[-1] == "S":        # rwkv state (B, H, hd, hd): shard hd
            return P(*lead, dp_axes, None, "model", None)
        if names[-1] == "h":        # rg-lru (B, RD)
            return P(*lead, dp_axes, "model")
        if names[-1] == "conv":     # (B, W-1, RD)
            return P(*lead, dp_axes, None, "model")
        if names[-1] in ("shift", "cmix"):  # (B, D)
            return P(*lead, dp_axes, None)
        return P(*lead, *([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, state)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
