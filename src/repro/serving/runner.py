"""Model runner: jitted device dispatch for the serving engine.

The bottom layer of the engine (scheduler -> block manager -> runner).
It owns everything that touches the device: the paged KV state, the
device mirror of the block tables AND of the per-slot sampling configs,
the jitted prefill / decode / verify / block-copy callables, and
sampling. It knows nothing about queues, refcounts, or request
lifecycle — the scheduler hands it fully-resolved work (token rows,
table rows, slot ids, SamplingParams) and gets tokens back.

Bucketed batched prefill: queued prompts are padded to a small set of
power-of-two suffix-length buckets and dispatched several at a time
through `lm.prefill_paged` (batch width is also bucketed to powers of
two, padded with inert rows that write only the null block). One jitted
instance serves every batch with the same (width, length) bucket, so
the number of prefill compilations is bounded by
len(width_buckets) * len(length_buckets) — not by the number of
distinct prompt lengths in the workload. `prefill_shapes` records the
distinct compiled shapes so benchmarks can assert the bound.

Bucketed verify (speculative decoding): draft chains are padded to a
small grid of chain-length buckets (`verify_buckets`, powers of two up
to speculate+1) and dispatched through `lm.decode_verify_paged` — the
same trick, so verify compilations are bounded by the bucket grid, not
by the per-step draft lengths. `verify()` returns the emitted token and
accept count at every chain position (greedy compare or Leviathan
accept/reject — see serving/sampling.py); `commit()` then restores each
lane's recurrent state at its accepted length (attention needs no
commit — stale K/V past the accepted point is position-masked until
overwritten).

Per-request sampling configs are DATA: temperature / top-k / top-p /
seed ride through every dispatch as (num_slots,) arrays (mirroring the
block tables), so one compiled instance per shape bucket serves every
mix of configs, and the compile count never depends on how many
distinct SamplingParams a workload carries. Each bucket has at most
FOUR traces — {argmax fast path, full sampler} x {with, without the
top-`max_logprobs` alternative-logprob side output} — so the bound is
4x the bucket grid (2x while no request asks for logprobs). Randomness
is
position-keyed per request (fold_in(PRNGKey(seed), pos)); the runner
holds no sampler state at all, which is what makes a request's stream
independent of batch composition.

All jitted state is donated, so pools update in place. The bucket-grid
helpers live in `serving/bucketing.py` (shared with the bench's shape
assertions).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import kv_cache, sampling
from repro.serving.block_manager import NULL_BLOCK
from repro.serving.bucketing import (chain_buckets, next_pow2,  # noqa: F401
                                     normalize_buckets, pick_bucket,
                                     pow2_buckets, width_buckets)
from repro.serving.observability import NULL_OBS, Observability
from repro.serving.sampling import GREEDY, SamplingParams

RECURRENT_KINDS = ("rwkv", "rec")

# default chunked-prefill budget (tokens per prefill dispatch); prompts
# whose suffix exceeds the largest prefill bucket are split into chunks
# of at most this size — see ModelRunner(prefill_chunk=...)
DEFAULT_PREFILL_CHUNK = 2048


@dataclasses.dataclass
class PrefillRow:
    """One sequence of a prefill batch, fully resolved by the scheduler:
    suffix tokens to compute, how much of the prompt is cache-hit, the
    request's sampling config, and where the results land."""
    tokens: np.ndarray          # (P,) the FULL prompt, int32
    cached_len: int             # prompt tokens already present in blocks
    slot: int                   # decode lane (recurrent state index)
    table_row: np.ndarray       # (max_blocks,) int32, NULL padded
    sampling: SamplingParams = GREEDY

    @property
    def start(self) -> int:     # first computed position
        return min(self.cached_len, len(self.tokens) - 1)

    @property
    def suffix_len(self) -> int:
        return len(self.tokens) - self.start


class ModelRunner:
    """Owns device state + jitted dispatch. See module docstring."""

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int,
                 block_size: int, num_blocks: int, max_blocks_per_seq: int,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 prefill_max_batch: int = 4,
                 prefill_chunk: Optional[int] = None, speculate: int = 0,
                 max_logprobs: int = 8, kv_dtype: str = "fp16",
                 obs: Observability = NULL_OBS,
                 now_fn: Optional[Callable[[], float]] = None):
        if kv_dtype not in kv_cache.KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}; expected "
                             f"{kv_cache.KV_DTYPES}")
        self.cfg = cfg
        self._obs = obs or NULL_OBS
        self._now = now_fn or (lambda: 0.0)
        # dispatch counters resolved once (no-ops when obs is off)
        self._c_prefill = self._obs.counter("prefill_dispatches_total")
        self._c_decode = self._obs.counter("decode_dispatches_total")
        self._c_verify = self._obs.counter("verify_dispatches_total")
        self._c_copies = self._obs.counter("block_copies_total")
        # compiled-variant sets: a dispatch whose (bucket, static args)
        # combination is unseen triggers a jit compile — the trace flags
        # it `first_dispatch` so compile stalls are attributable
        self._prefill_variants: set = set()
        self._decode_variants: set = set()
        self._verify_variants: set = set()
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.kv_dtype = kv_dtype
        self.state = kv_cache.init_paged_state(cfg, num_slots, num_blocks,
                                               block_size, kv_dtype)
        self.cache_bytes = kv_cache.paged_bytes(cfg, num_blocks, block_size,
                                                kv_dtype)
        self.block_bytes = kv_cache.block_bytes(cfg, block_size, kv_dtype)
        self._has_recurrent = any(
            k in RECURRENT_KINDS
            for k in cfg.block_pattern + cfg.prefix_pattern)

        max_len = max_blocks_per_seq * block_size
        self.prefill_buckets = normalize_buckets(
            prefill_buckets, max_len, start=min(16, next_pow2(max_len)))
        # chunked prefill budget: suffixes longer than the largest
        # prefill bucket are split by the scheduler into chunks of at
        # most this many tokens, dispatched across successive steps.
        # None = auto (DEFAULT_PREFILL_CHUNK, capped to the grid — a
        # no-op for short-context configs whose grid already covers
        # max_len); 0 = disabled (oversized suffixes are rejected with
        # an actionable error instead of compiling an oversized
        # variant). The budget is bucket-aligned, and the bucket grid
        # is capped at it so no dispatch ever exceeds the budget.
        if prefill_chunk is None:
            prefill_chunk = DEFAULT_PREFILL_CHUNK
        if prefill_chunk:
            budget = pick_bucket(min(prefill_chunk, max_len),
                                 self.prefill_buckets)
            self.prefill_buckets = [b for b in self.prefill_buckets
                                    if b <= budget]
            self.prefill_chunk = budget
        else:
            self.prefill_chunk = 0
            if prefill_buckets:
                # chunking explicitly off + an explicit grid: the grid
                # is a hard cap (no silent extension to max_len), so an
                # oversized suffix raises the actionable suffix_bucket
                # error instead of compiling an unbounded variant
                self.prefill_buckets = sorted(
                    set(int(b) for b in prefill_buckets))
        self.prefill_max_batch = max(1, prefill_max_batch)
        self.width_buckets = width_buckets(self.prefill_max_batch)
        self.speculate = max(0, speculate)
        self.verify_buckets = chain_buckets(self.speculate)

        # host tables + device mirror (refreshed lazily when dirty)
        self._tables = np.zeros((num_slots, max_blocks_per_seq), np.int32)
        self._tables_dev = jnp.asarray(self._tables)
        self._tables_dirty = False

        # per-slot sampling configs, the same pattern as the tables:
        # host arrays of plain data, mirrored to the device lazily
        self._temps = np.zeros(num_slots, np.float32)
        self._topks = np.zeros(num_slots, np.int32)
        self._topps = np.ones(num_slots, np.float32)
        self._seeds = np.zeros(num_slots, np.int32)
        self._wantk = np.zeros(num_slots, np.int32)   # requested logprob k
        self._sampling_dev = None
        # static top-k width of the alternative-logprob side output (one
        # compiled width serves every per-request k <= max_logprobs; the
        # scheduler slices each request's k columns host-side)
        self.max_logprobs = max(1, min(max_logprobs, cfg.vocab_size))

        # telemetry; *_shapes are process-cumulative (compilations
        # persist across runs), the counters are reset per run
        self.prefill_shapes: set = set()     # distinct (width, Ls) dispatched
        self.verify_shapes: set = set()      # distinct chain buckets T
        self._snaps = None                   # pending recurrent snapshots
        self.reset_stats()

        K = self.max_logprobs

        def _decode(state, tokens, positions, tables, temps, topks, topps,
                    seeds, do_sample, want_alt):
            logits, state = lm.decode_step_paged(params, cfg, state, tokens,
                                                 positions, tables)
            if do_sample:
                tok, lp = sampling.sample_tokens(logits, positions, temps,
                                                 topks, topps, seeds)
            else:
                tok, lp = sampling.greedy_tokens(logits)
            alt = sampling.top_alternatives(logits, K) if want_alt else None
            return tok, lp, alt, state

        self._decode_fn = jax.jit(_decode, donate_argnums=(0,),
                                  static_argnums=(8, 9))

        def _verify(state, tokens, positions, counts, tables, temps, topks,
                    topps, seeds, do_sample, want_alt):
            logits, state, snaps = lm.decode_verify_paged(
                params, cfg, state, tokens, positions, counts, tables)
            if do_sample:
                emit, accept, lp = sampling.verify_tokens(
                    logits, tokens, counts, positions, temps, topks, topps,
                    seeds)
            else:
                emit, accept, lp = sampling.greedy_verify_tokens(
                    logits, tokens, counts)
            alt = sampling.top_alternatives(logits, K) if want_alt else None
            return emit, accept, lp, alt, state, snaps

        self._verify_fn = jax.jit(_verify, donate_argnums=(0,),
                                  static_argnums=(9, 10))

        def _commit(state, snaps, idx):
            return lm.commit_decode_state(cfg, state, snaps, idx)

        self._commit_fn = jax.jit(_commit, donate_argnums=(0,))

        def _prefill(state, toks, lengths, cached, rows, slots, resume):
            return lm.prefill_paged(params, cfg, state, toks, lengths,
                                    cached, rows, slots, resume=resume)

        self._prefill_fn = jax.jit(_prefill, donate_argnums=(0,),
                                   static_argnums=(6,))

        def _first(last, positions, temps, topks, topps, seeds, do_sample,
                   want_alt):
            if do_sample:
                tok, lp = sampling.sample_tokens(last, positions, temps,
                                                 topks, topps, seeds)
            else:
                tok, lp = sampling.greedy_tokens(last)
            alt = sampling.top_alternatives(last, K) if want_alt else None
            return tok, lp, alt

        self._first_fn = jax.jit(_first, static_argnums=(6, 7))

        def _copy(state, src, dst):
            return kv_cache.copy_block(cfg, state, src, dst)

        self._copy_fn = jax.jit(_copy, donate_argnums=(0,))

        # host-tier payload movement: a single-block jitted gather
        # (demotion) and a width-bucketed jitted scatter (revival).
        # Promotion batches pad to `promote_buckets` via pick_bucket, so
        # revivals never compile outside the bucket grid
        # (`promote_shapes` records dispatched widths for the bound
        # assertion, like prefill_shapes).
        self.promote_buckets = pow2_buckets(max_blocks_per_seq)
        self.promote_shapes: set = set()

        def _gather(state, ids):
            return kv_cache.gather_blocks(cfg, state, ids)

        self._gather_fn = jax.jit(_gather)

        def _upload(state, ids, payload):
            return kv_cache.scatter_blocks(cfg, state, ids, payload)

        self._upload_fn = jax.jit(_upload, donate_argnums=(0,))

    def reset_stats(self) -> None:
        self.prefill_dispatches = 0
        self.prefill_padded_tokens = 0       # token slots incl. padding
        self.prefill_computed_tokens = 0     # true suffix tokens computed
        self.prefill_peak_score_bytes = 0    # analytic peak f32 score tile
        self.block_copies = 0
        self.verify_dispatches = 0
        self.verify_padded_tokens = 0        # chain slots incl. padding
        self.verify_chain_tokens = 0         # true chain tokens verified
        self.sampled_dispatches = 0          # decode/verify full-sampler uses

    # ------------------------------------------------------------------
    # block tables
    # ------------------------------------------------------------------

    def write_table(self, slot: int, row: np.ndarray) -> None:
        self._tables[slot] = row
        self._tables_dirty = True

    def clear_table(self, slot: int) -> None:
        self._tables[slot] = NULL_BLOCK
        self._tables_dirty = True
        self.clear_sampling(slot)

    def _tables_device(self):
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables)
            self._tables_dirty = False
        return self._tables_dev

    # ------------------------------------------------------------------
    # per-slot sampling configs
    # ------------------------------------------------------------------

    def set_sampling(self, slot: int, sp: SamplingParams) -> None:
        self._temps[slot] = sp.temperature
        self._topks[slot] = sp.top_k
        self._topps[slot] = sp.top_p
        self._seeds[slot] = sampling.seed32(sp.seed)
        self._wantk[slot] = min(sp.logprobs, self.max_logprobs)
        self._sampling_dev = None

    def clear_sampling(self, slot: int) -> None:
        self._temps[slot] = 0.0
        self._topks[slot] = 0
        self._topps[slot] = 1.0
        self._seeds[slot] = 0
        self._wantk[slot] = 0
        self._sampling_dev = None

    @property
    def any_sampled(self) -> bool:
        """True while any live slot samples (temperature > 0) — selects
        the full-sampler trace over the argmax fast path."""
        return bool(self._temps.max() > 0.0)

    @property
    def any_alt(self) -> bool:
        """True while any live slot asked for alternative logprobs —
        selects the trace with the top-k side output."""
        return bool(self._wantk.max() > 0)

    def _sampling_device(self):
        if self._sampling_dev is None:
            self._sampling_dev = (jnp.asarray(self._temps),
                                  jnp.asarray(self._topks),
                                  jnp.asarray(self._topps),
                                  jnp.asarray(self._seeds))
        return self._sampling_dev

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def suffix_bucket(self, n: int) -> int:
        """Smallest configured length bucket covering n suffix tokens.

        A suffix that no bucket covers would otherwise fall through to
        an oversized jit variant (the full dense score tensor) — the
        scheduler must route it to chunked admission first, so reaching
        here oversized is an error, with the fix spelled out."""
        if n > self.prefill_buckets[-1]:
            raise ValueError(
                f"prompt suffix of {n} tokens exceeds the largest "
                f"prefill bucket ({self.prefill_buckets[-1]}) and "
                f"chunked prefill is disabled (prefill_chunk=0); enable "
                f"chunked admission (prefill_chunk > 0, serve.py "
                f"--prefill-chunk) or widen --prefill-buckets")
        return pick_bucket(n, self.prefill_buckets)

    def chain_bucket(self, n: int) -> int:
        """Smallest verify bucket covering an n-token draft chain."""
        return pick_bucket(n, self.verify_buckets)

    def prefill(self, rows: List[PrefillRow], *, resume: bool = False,
                chunk: Optional[Tuple[int, int]] = None):
        """Run one bucketed batched prefill and sample each row's first
        token from its true-last-position logits with the row's own
        SamplingParams (position-keyed on the last prompt position).
        Blocks until done (the caller's TTFT clock covers it). Returns
        ((len(rows),) int32 tokens, (len(rows),) float32 logprobs,
        alt) where alt is None unless a row asked for logprobs — then
        ((len(rows), max_logprobs) int32 ids, (..., max_logprobs)
        float32 logprobs) of the top alternatives at each row's last
        prompt position.

        resume=True marks a chunked-prefill continuation (chunk >= 1 of
        a split admission): recurrent layers pick their scanned state up
        from the slot where the previous chunk left it — a separate jit
        trace, so it rides in the dispatch-variant key. `chunk` is
        (index, total) of the admission's chunk sequence, recorded on
        the prefill step trace so Perfetto can attribute TTFT across a
        multi-chunk admission; for non-final chunks the sampled "first
        token" is a mid-prompt artifact the scheduler discards."""
        n = len(rows)
        obs = self._obs
        t0 = self._now() if obs.enabled else 0.0
        ls = self.suffix_bucket(max(r.suffix_len for r in rows))
        width = pick_bucket(n, self.width_buckets)
        toks = np.zeros((width, ls), np.int32)
        lengths = np.zeros(width, np.int32)
        cached = np.zeros(width, np.int32)
        tables = np.full((width, self.max_blocks_per_seq), NULL_BLOCK,
                         np.int32)
        slots = np.full(width, self.num_slots, np.int32)   # pad rows drop
        temps = np.zeros(width, np.float32)
        topks = np.zeros(width, np.int32)
        topps = np.ones(width, np.float32)
        seeds = np.zeros(width, np.int32)
        for i, r in enumerate(rows):
            suf = r.tokens[r.start:]
            toks[i, :len(suf)] = suf
            lengths[i] = len(r.tokens)
            cached[i] = r.cached_len
            tables[i] = r.table_row
            slots[i] = r.slot
            temps[i] = r.sampling.temperature
            topks[i] = r.sampling.top_k
            topps[i] = r.sampling.top_p
            seeds[i] = sampling.seed32(r.sampling.seed)
        self.prefill_shapes.add((width, ls))
        self.prefill_dispatches += 1
        self.prefill_padded_tokens += width * ls
        self.prefill_computed_tokens += sum(r.suffix_len for r in rows)
        # analytic peak attention-score bytes for this dispatch: the
        # streamed path (attention.streamed_paged_attention) bounds the
        # pool band at attn_chunk keys, plus the (ls, ls) suffix tile —
        # f32 scores per head. Benchmarks assert this stays flat as the
        # prompt grows past the chunk budget.
        kv_band = min(self.max_blocks_per_seq * self.block_size,
                      self.cfg.attn_chunk)
        score_bytes = 4 * width * self.cfg.n_heads * ls * (kv_band + ls)
        self.prefill_peak_score_bytes = max(self.prefill_peak_score_bytes,
                                            score_bytes)

        last, self.state = self._prefill_fn(
            self.state, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(cached), jnp.asarray(tables), jnp.asarray(slots),
            resume)
        do_sample = bool(temps.max() > 0.0)
        want_alt = any(r.sampling.logprobs for r in rows)
        first, lp, alt = self._first_fn(
            last, jnp.asarray(np.maximum(lengths - 1, 0)),
            jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps),
            jnp.asarray(seeds), do_sample, want_alt)
        out = (np.asarray(first, np.int32)[:n],
               np.asarray(lp, np.float32)[:n], self._host_alt(alt, n))
        if obs.enabled:
            variant = (width, ls, do_sample, want_alt, resume)
            self._c_prefill.inc()
            extra = {}
            if chunk is not None:
                extra = {"chunk": chunk[0], "chunks_total": chunk[1]}
            obs.step("prefill", t0, self._now(), batch=n,
                     bucket=[width, ls],
                     first_dispatch=variant not in self._prefill_variants,
                     emitted=n,
                     computed_tokens=sum(r.suffix_len for r in rows),
                     padded_tokens=width * ls,
                     cached_tokens=sum(r.start for r in rows), **extra)
            self._prefill_variants.add(variant)
        return out

    @staticmethod
    def _host_alt(alt, n: Optional[int] = None):
        if alt is None:
            return None
        ids, lps = alt
        ids = np.asarray(ids, np.int32)
        lps = np.asarray(lps, np.float32)
        return (ids[:n], lps[:n]) if n is not None else (ids, lps)

    def decode(self, tokens: np.ndarray, positions: np.ndarray):
        """One batched decode step over all lanes. tokens/positions:
        (num_slots,) int32 host arrays. Returns ((num_slots,) int32
        next tokens, (num_slots,) float32 chosen logprobs, alt — None
        or the top-max_logprobs ((num_slots, K) ids, (num_slots, K)
        logprobs) when any live slot asked for alternatives)."""
        obs = self._obs
        t0 = self._now() if obs.enabled else 0.0
        do_sample = self.any_sampled
        if do_sample:
            self.sampled_dispatches += 1
        want_alt = self.any_alt
        temps, topks, topps, seeds = self._sampling_device()
        next_tok, lp, alt, self.state = self._decode_fn(
            self.state, jnp.asarray(tokens), jnp.asarray(positions),
            self._tables_device(), temps, topks, topps, seeds, do_sample,
            want_alt)
        out = np.asarray(next_tok), np.asarray(lp), self._host_alt(alt)
        if obs.enabled:
            variant = (do_sample, want_alt)
            self._c_decode.inc()
            obs.step("decode", t0, self._now(), batch=self.num_slots,
                     first_dispatch=variant not in self._decode_variants,
                     sampled=do_sample)
            self._decode_variants.add(variant)
        return out

    def verify(self, tokens: np.ndarray, positions: np.ndarray,
               counts: np.ndarray):
        """One batched multi-token verify dispatch. tokens: (num_slots,
        T) draft chains right-padded to a verify bucket; positions /
        counts: (num_slots,) int32 (counts 0 = lane sits out). Returns
        (emitted tokens (num_slots, T) int32 — valid at chain indices
        0..accept —, accept counts (num_slots,) int32, chosen logprobs
        (num_slots, T) float32, alt — None or the per-position
        top-max_logprobs ((num_slots, T, K) ids, (num_slots, T, K)
        logprobs)). Greedy lanes emit the model argmax at
        every position (accept = longest agreeing draft prefix, exactly
        the bit-identity rule); sampled lanes run Leviathan
        accept/reject with residual resampling (serving/sampling.py).
        Recurrent snapshots are held until the matching `commit`."""
        T = tokens.shape[1]
        obs = self._obs
        t0 = self._now() if obs.enabled else 0.0
        self.verify_shapes.add(T)
        self.verify_dispatches += 1
        self.verify_padded_tokens += tokens.shape[0] * T
        self.verify_chain_tokens += int(counts.sum())
        do_sample = self.any_sampled
        if do_sample:
            self.sampled_dispatches += 1
        want_alt = self.any_alt
        temps, topks, topps, seeds = self._sampling_device()
        emit, accept, lp, alt, self.state, self._snaps = self._verify_fn(
            self.state, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(counts), self._tables_device(), temps, topks,
            topps, seeds, do_sample, want_alt)
        out = (np.asarray(emit), np.asarray(accept), np.asarray(lp),
               self._host_alt(alt))
        if obs.enabled:
            variant = (T, do_sample, want_alt)
            self._c_verify.inc()
            obs.step("verify", t0, self._now(), batch=tokens.shape[0],
                     bucket=T,
                     first_dispatch=variant not in self._verify_variants,
                     chain_tokens=int(counts.sum()),
                     padded_tokens=tokens.shape[0] * T,
                     sampled=do_sample)
            self._verify_variants.add(variant)
        return out

    def commit(self, idx: np.ndarray) -> None:
        """Commit per-lane recurrent state at `idx` accepted chain
        tokens (0 = keep the pre-verify state). Must follow every
        `verify`; a no-op for pure-attention architectures, whose
        rollback is entirely positional."""
        if self._has_recurrent and self._snaps is not None:
            self.state = self._commit_fn(self.state, self._snaps,
                                         jnp.asarray(idx))
        self._snaps = None

    def copy_block(self, src: int, dst: int) -> None:
        """Device-side copy-on-write: clone block `src`'s K/V into `dst`
        in every attention pool."""
        self.state = self._copy_fn(self.state, jnp.int32(src),
                                   jnp.int32(dst))
        self.block_copies += 1
        self._c_copies.inc()

    # ------------------------------------------------------------------
    # host-tier payload movement (BlockAllocator demotion / revival)
    # ------------------------------------------------------------------

    def fetch_block(self, block: int):
        """Device -> host: one block's payload from every attention pool
        (a kv_cache.gather_blocks tree of (1, ...) numpy leaves;
        quantized pools include the scale tables verbatim) — the
        allocator's host-tier demotion callback."""
        payload = self._gather_fn(self.state,
                                  jnp.asarray([block], jnp.int32))
        return jax.device_get(payload)

    def upload_blocks(self, ids: Sequence[int], payloads: Sequence) -> None:
        """Host -> device: scatter demoted payloads back into the pools
        at `ids` (the allocator's revival callback). The batch pads to a
        promote_buckets width — pad lanes target the reserved null
        block — so one jitted scatter per bucket width serves every
        revival."""
        n = len(ids)
        w = pick_bucket(n, self.promote_buckets)
        self.promote_shapes.add(w)
        idarr = np.full(w, NULL_BLOCK, np.int32)
        idarr[:n] = ids

        def cat(*leaves):
            out = np.concatenate(leaves, axis=0)
            if w > n:
                pad = np.zeros((w - n,) + out.shape[1:], out.dtype)
                out = np.concatenate([out, pad], axis=0)
            return out

        payload = jax.tree.map(cat, payloads[0], *payloads[1:])
        self.state = self._upload_fn(self.state, jnp.asarray(idarr),
                                     payload)
