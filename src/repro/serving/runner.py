"""Model runner: jitted device dispatch for the serving engine.

The bottom layer of the engine (scheduler -> block manager -> runner).
It owns everything that touches the device: the paged KV state, the
device mirror of the block tables, the jitted prefill / decode / block-
copy callables, and sampling. It knows nothing about queues, refcounts,
or request lifecycle — the scheduler hands it fully-resolved work
(token rows, table rows, slot ids) and gets tokens back.

Bucketed batched prefill: queued prompts are padded to a small set of
power-of-two suffix-length buckets and dispatched several at a time
through `lm.prefill_paged` (batch width is also bucketed to powers of
two, padded with inert rows that write only the null block). One jitted
instance serves every batch with the same (width, length) bucket, so
the number of prefill compilations is bounded by
len(width_buckets) * len(length_buckets) — not by the number of
distinct prompt lengths in the workload, which is what made the
one-sequence-per-jit-call admission path recompile-heavy under mixed
traffic. `prefill_shapes` records the distinct compiled shapes so
benchmarks can assert the bound.

All jitted state is donated, so pools update in place.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import kv_cache
from repro.serving.block_manager import NULL_BLOCK


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class PrefillRow:
    """One sequence of a prefill batch, fully resolved by the scheduler:
    suffix tokens to compute, how much of the prompt is cache-hit, and
    where the results land."""
    tokens: np.ndarray          # (P,) the FULL prompt, int32
    cached_len: int             # prompt tokens already present in blocks
    slot: int                   # decode lane (recurrent state index)
    table_row: np.ndarray       # (max_blocks,) int32, NULL padded

    @property
    def start(self) -> int:     # first computed position
        return min(self.cached_len, len(self.tokens) - 1)

    @property
    def suffix_len(self) -> int:
        return len(self.tokens) - self.start


class ModelRunner:
    """Owns device state + jitted dispatch. See module docstring."""

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int,
                 block_size: int, num_blocks: int, max_blocks_per_seq: int,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 prefill_max_batch: int = 4):
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self.state = kv_cache.init_paged_state(cfg, num_slots, num_blocks,
                                               block_size)
        self.cache_bytes = kv_cache.paged_bytes(cfg, num_blocks, block_size)

        max_len = max_blocks_per_seq * block_size
        if prefill_buckets:
            self.prefill_buckets = sorted(set(int(b) for b in prefill_buckets))
        else:
            self.prefill_buckets, b = [], min(16, next_pow2(max_len))
            while b < max_len:
                self.prefill_buckets.append(b)
                b *= 2
        if not self.prefill_buckets or self.prefill_buckets[-1] < max_len:
            self.prefill_buckets.append(next_pow2(max_len))
        self.prefill_max_batch = max(1, prefill_max_batch)
        self.width_buckets = []
        w = 1
        while w < self.prefill_max_batch:
            self.width_buckets.append(w)
            w *= 2
        self.width_buckets.append(self.prefill_max_batch)

        # host tables + device mirror (refreshed lazily when dirty)
        self._tables = np.zeros((num_slots, max_blocks_per_seq), np.int32)
        self._tables_dev = jnp.asarray(self._tables)
        self._tables_dirty = False

        # telemetry; prefill_shapes is process-cumulative (compilations
        # persist across runs), the counters are reset per run
        self.prefill_shapes: set = set()     # distinct (width, Ls) dispatched
        self.reset_stats()

        def _decode(state, tokens, positions, tables, key):
            logits, state = lm.decode_step_paged(params, cfg, state, tokens,
                                                 positions, tables)
            if temperature > 0:
                tok = jax.random.categorical(key, logits / temperature, -1)
            else:
                tok = jnp.argmax(logits, -1)
            return tok.astype(jnp.int32), state

        self._decode_fn = jax.jit(_decode, donate_argnums=(0,))

        def _prefill(state, toks, lengths, cached, rows, slots):
            return lm.prefill_paged(params, cfg, state, toks, lengths,
                                    cached, rows, slots)

        self._prefill_fn = jax.jit(_prefill, donate_argnums=(0,))

        def _copy(state, src, dst):
            return kv_cache.copy_block(cfg, state, src, dst)

        self._copy_fn = jax.jit(_copy, donate_argnums=(0,))

    def reset_stats(self) -> None:
        self.prefill_dispatches = 0
        self.prefill_padded_tokens = 0       # token slots incl. padding
        self.prefill_computed_tokens = 0     # true suffix tokens computed
        self.block_copies = 0

    # ------------------------------------------------------------------
    # block tables
    # ------------------------------------------------------------------

    def write_table(self, slot: int, row: np.ndarray) -> None:
        self._tables[slot] = row
        self._tables_dirty = True

    def clear_table(self, slot: int) -> None:
        self._tables[slot] = NULL_BLOCK
        self._tables_dirty = True

    def _tables_device(self):
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables)
            self._tables_dirty = False
        return self._tables_dev

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def suffix_bucket(self, n: int) -> int:
        """Smallest configured length bucket covering n suffix tokens."""
        for b in self.prefill_buckets:
            if b >= n:
                return b
        return self.prefill_buckets[-1]

    def prefill(self, rows: List[PrefillRow]) -> np.ndarray:
        """Run one bucketed batched prefill and sample each row's first
        token from its true-last-position logits. Blocks until done (the
        caller's TTFT clock covers it). Returns (len(rows),) int32."""
        n = len(rows)
        ls = self.suffix_bucket(max(r.suffix_len for r in rows))
        width = next((w for w in self.width_buckets if w >= n), n)
        toks = np.zeros((width, ls), np.int32)
        lengths = np.zeros(width, np.int32)
        cached = np.zeros(width, np.int32)
        tables = np.full((width, self.max_blocks_per_seq), NULL_BLOCK,
                         np.int32)
        slots = np.full(width, self.num_slots, np.int32)   # pad rows drop
        for i, r in enumerate(rows):
            suf = r.tokens[r.start:]
            toks[i, :len(suf)] = suf
            lengths[i] = len(r.tokens)
            cached[i] = r.cached_len
            tables[i] = r.table_row
            slots[i] = r.slot
        self.prefill_shapes.add((width, ls))
        self.prefill_dispatches += 1
        self.prefill_padded_tokens += width * ls
        self.prefill_computed_tokens += sum(r.suffix_len for r in rows)

        last, self.state = self._prefill_fn(
            self.state, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(cached), jnp.asarray(tables), jnp.asarray(slots))
        last = last[:n]
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            first = jax.random.categorical(sub, last / self.temperature, -1)
            return np.asarray(first, np.int32)
        return np.asarray(jnp.argmax(last, -1), np.int32)

    def decode(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        """One batched decode step over all lanes. tokens/positions:
        (num_slots,) int32 host arrays. Returns sampled (num_slots,)."""
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
        else:
            sub = self._key              # unused by the greedy trace
        next_tok, self.state = self._decode_fn(
            self.state, jnp.asarray(tokens), jnp.asarray(positions),
            self._tables_device(), sub)
        return np.asarray(next_tok)

    def copy_block(self, src: int, dst: int) -> None:
        """Device-side copy-on-write: clone block `src`'s K/V into `dst`
        in every attention pool."""
        self.state = self._copy_fn(self.state, jnp.int32(src),
                                   jnp.int32(dst))
        self.block_copies += 1
