"""Replica: one full serving-engine stack behind a routable facade.

The unit a cluster router places work onto. Each replica owns a
COMPLETE engine — scheduler, block manager, runner, its own paged
device pools and jitted dispatches — exactly the paper's distribution
model: all per-token state (paged KV blocks, recurrent slot snapshots,
the content-hash prefix index) stays replica-LOCAL, and the only
things that ever cross the replica boundary are placement decisions
(a Request) and completions/stream events coming back. Nothing else is
shared, so replicas never synchronize with each other.

What the router reads from a replica:

  snapshot()       a ReplicaSnapshot of occupancy telemetry — queue
                   depth, active/free slots, free blocks, cached-block
                   count (built on the scheduler's SchedulerStats
                   accessor, not internals)
  probe_prefix()   the prefix-affinity signal: how many leading tokens
                   of a prompt this replica's BlockAllocator already
                   holds (a read-only `match_prefix` content-hash
                   probe — the ROADMAP's "affinity for free")

What the router does to a replica:

  submit()/step()  place a request / advance the engine one iteration
  take_queued()    drain: pull queued-but-unadmitted requests back out
                   so a disabled replica's backlog can requeue on the
                   rest of the cluster (admitted requests keep their
                   slots and finish where they are — placement is
                   sticky for a request's lifetime)
  begin_run(t0)    reset per-run telemetry and align this replica's
                   clock with the cluster clock so timestamps merge
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.engine import ServingEngine
from repro.serving.observability import NULL_OBS, Observability
from repro.serving.scheduler import Completion, Request, SchedulerStats


@dataclasses.dataclass(frozen=True)
class ReplicaSnapshot:
    """Occupancy/telemetry snapshot of one replica (router input):
    replica identity + the scheduler's structured SchedulerStats,
    re-exposed as flat read-only properties for placement code."""
    replica_id: int
    enabled: bool
    stats: SchedulerStats

    @property
    def queue_depth(self) -> int:     # placed here, not yet admitted
        return self.stats.queue_depth

    @property
    def active_slots(self) -> int:
        return self.stats.active_slots

    @property
    def free_slots(self) -> int:
        return self.stats.free_slots

    @property
    def free_blocks(self) -> int:     # allocatable KV blocks
        return self.stats.free_blocks

    @property
    def cached_blocks(self) -> int:   # cached-free warm prefix blocks
        return self.stats.cached_blocks

    @property
    def indexed_blocks(self) -> int:  # blocks published in the index
        return self.stats.indexed_blocks

    @property
    def spilled_blocks(self) -> int:  # blocks demoted to the host tier
        return getattr(self.stats, "spilled_blocks", 0)

    @property
    def load(self) -> int:
        """Slot + queue occupancy — the least-loaded placement signal."""
        return self.stats.load


class Replica:
    """One engine stack with an id, an enable/drain bit, and the
    occupancy + affinity probes the router places on. All engine
    keyword arguments pass through to `ServingEngine`."""

    def __init__(self, params, cfg, *, replica_id: int = 0,
                 obs: Observability = NULL_OBS, **engine_kwargs):
        self.replica_id = replica_id
        self.enabled = True
        # each replica publishes through a view of the shared recorder
        # scoped to its id: replica-labeled instruments, pid=replica_id
        # tracks in the exported trace
        self.engine = ServingEngine(
            params, cfg, obs=(obs or NULL_OBS).scoped(replica_id),
            **engine_kwargs)
        self.placed = 0               # requests currently owned (net of
        #                               drained requeues) — telemetry

    # ------------------------------------------------------------------
    # engine pass-throughs
    # ------------------------------------------------------------------

    @property
    def scheduler(self):
        return self.engine.scheduler

    @property
    def num_slots(self) -> int:
        return self.engine.num_slots

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    def submit(self, req: Request) -> None:
        self.engine.submit(req)
        self.placed += 1

    def step(self) -> None:
        self.engine.step()

    def begin_run(self, t0: Optional[float] = None) -> None:
        self.engine.begin_run(t0)
        self.placed = 0

    def align_clock(self, t0: float) -> None:
        """Adopt the cluster clock origin without resetting telemetry
        (mid-run activation — see ServingEngine.align_clock)."""
        self.engine.align_clock(t0)

    def reset_prefix_cache(self) -> None:
        self.engine.reset_prefix_cache()

    # ------------------------------------------------------------------
    # router probes
    # ------------------------------------------------------------------

    def snapshot(self) -> ReplicaSnapshot:
        return ReplicaSnapshot(replica_id=self.replica_id,
                               enabled=self.enabled,
                               stats=self.engine.stats())

    def probe_prefix(self, prompt) -> int:
        """Affinity signal: leading tokens of `prompt` this replica's
        allocator already holds (read-only content-hash probe — takes
        no references, revives nothing, capped at len(prompt) - 1 like
        admission's own accounting). Tokens whose blocks were demoted
        to the host tier count too — the replica can revive them on
        admission, so they are real affinity the router should see.
        0 when the replica has prefix caching off."""
        if not self.engine.prefix_cache:
            return 0
        prompt = np.asarray(prompt)
        match = self.engine.allocator.match_prefix(prompt, promote=False)
        cached = match.tokens(self.engine.block_size) + match.spilled_tokens
        return min(cached, len(prompt) - 1)

    # ------------------------------------------------------------------
    # drain / completion collection
    # ------------------------------------------------------------------

    def take_queued(self) -> List[Request]:
        """Pull queued-but-unadmitted requests out (drain/failover);
        the router requeues them elsewhere. Active slots keep running."""
        out = self.engine.scheduler.take_queued()
        self.placed -= len(out)
        return out

    def take_completions(self) -> List[Completion]:
        done = self.engine.scheduler.completions
        self.engine.scheduler.completions = []
        return done
