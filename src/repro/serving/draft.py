"""Draft proposers for speculative decoding.

A proposer guesses the next k tokens of a sequence cheaply; the runner
verifies the whole guess in ONE batched model dispatch and the
scheduler accepts the longest agreeing prefix (plus the one token the
model produced anyway) — the serving-side version of the paper's move
of amortizing one expensive synchronization over a batch of cheap
local work.

`NGramProposer` is prompt-lookup decoding: no draft model, no extra
device work. It matches the sequence's most recent n-gram against its
own earlier history (prompt + generated tokens) and proposes the
tokens that followed the match. Strong on repetitive continuations
(code, templated text, self-looping generations); proposes nothing
when no n-gram recurs, so the engine falls back to plain decode with
zero overhead. The seam for a draft-model proposer later is the same
`propose(history, k)` interface.
"""
from __future__ import annotations

from typing import List, Sequence


class NGramProposer:
    """Prompt-lookup draft proposer over one sequence's token history.

    max_ngram     longest n-gram to try to match (falls back to shorter
                  ones down to `min_ngram` before giving up)
    min_ngram     shortest n-gram considered a real match
    max_lookback  only the trailing `max_lookback` history tokens are
                  scanned — bounds the per-step host work to O(lookback)
                  instead of O(full history) on the serial engine loop
                  (repeats worth speculating on are local anyway)
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 max_lookback: int = 512):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError((min_ngram, max_ngram))
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_lookback = max_lookback

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Up to k draft tokens continuing `history`, or [] when no
        n-gram suffix of the history recurs earlier in it. The MOST
        RECENT earlier occurrence wins (locality: loops and templated
        spans repeat their latest iteration)."""
        if k <= 0:
            return []
        hist = history if isinstance(history, list) else list(history)
        if len(hist) > self.max_lookback:
            hist = hist[-self.max_lookback:]
        n_max = min(self.max_ngram, len(hist) - 1)
        for n in range(n_max, self.min_ngram - 1, -1):
            pattern = hist[-n:]
            # scan right-to-left over earlier occurrences; the match
            # must end before the final position so at least one
            # continuation token exists
            for start in range(len(hist) - n - 1, -1, -1):
                if hist[start:start + n] == pattern:
                    cont = hist[start + n:start + n + k]
                    if cont:
                        return cont
        return []


def make_proposer(kind: str, *, ngram: int = 3) -> NGramProposer:
    """Proposer factory (`--draft` CLI values resolve here)."""
    if kind == "ngram":
        return NGramProposer(max_ngram=ngram)
    raise ValueError(f"unknown draft proposer {kind!r} "
                     f"(available: 'ngram')")
