"""Continuous-batching serving engine: slot-scheduled decode over paged KV.

The paper's tradeoff — hold a batch, amortize fixed costs over it, pay
synchronization only at coarse boundaries — applied to inference: the
engine holds a fixed-width decode batch of `num_slots` lanes; requests
queue, a scheduler admits them into free lanes, finished sequences are
evicted and replaced mid-flight so the batch stays full under sustained
load. Host<->device synchronization happens once per decode iteration for
the whole batch (one jitted dispatch), never per sequence.

Request lifecycle:
  queued -> admitted (blocks reserved, prompt prefilled in ONE jit call,
  first token sampled from the prefill logits) -> decoding (one lane of the
  batched decode_step_paged per iteration) -> finished (max_new_tokens or
  eos) -> evicted (blocks + lane recycled).

Admission reserves ceil((prompt + max_new) / block_size) blocks up front,
so an admitted request can never deadlock on cache memory (vLLM's
conservative-reservation mode); admission blocks on either lanes or
blocks running out.

All jitted state is donated, so pools update in place instead of being
copied every step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import kv_cache
from repro.serving.kv_cache import NULL_BLOCK, BlockAllocator


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0          # seconds on the engine clock (open loop)
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    arrival: float
    t_admit: float
    t_first_token: float
    t_done: float


@dataclasses.dataclass
class _Slot:
    req: Request
    blocks: List[int]
    pos: int                      # position of the next token to feed
    pending: int                  # token to feed at `pos`
    out: List[int]
    t_admit: float
    t_first: float


class ServingEngine:
    """Continuous-batching engine over a paged KV cache.

    num_slots   decode-batch width (lanes)
    block_size  tokens per physical KV block
    num_blocks  pool size; default sizes the pool to num_slots sequences
                of max_seq_len (plus the reserved null block)
    max_seq_len hard per-sequence cap (prompt + generated)
    """

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 512,
                 num_blocks: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0):
        if cfg.frontend != "none":
            raise NotImplementedError(
                "serving engine currently supports text LMs only")
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = -(-max_seq_len // block_size)
        self.max_seq_len = max_seq_len
        if num_blocks is None:
            num_blocks = 1 + num_slots * self.max_blocks_per_seq
        self.allocator = BlockAllocator(num_blocks)
        self.cache_bytes = kv_cache.paged_bytes(cfg, num_blocks, block_size)
        self.state = kv_cache.init_paged_state(cfg, num_slots, num_blocks,
                                               block_size)
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)

        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * num_slots
        self._tables = np.zeros((num_slots, self.max_blocks_per_seq),
                                np.int32)          # NULL_BLOCK padded
        self._completions: List[Completion] = []
        self._tables_dev = jnp.asarray(self._tables)  # refreshed when dirty
        self._tables_dirty = False
        self._t0 = time.perf_counter()  # engine clock origin (reset by run)
        self.steps = 0                # decode iterations executed
        self.busy_lane_steps = 0      # sum of active lanes over iterations

        def _decode(state, tokens, positions, tables, key):
            logits, state = lm.decode_step_paged(params, cfg, state, tokens,
                                                 positions, tables)
            if temperature > 0:
                tok = jax.random.categorical(key, logits / temperature, -1)
            else:
                tok = jnp.argmax(logits, -1)
            return tok.astype(jnp.int32), state

        self._decode_fn = jax.jit(_decode, donate_argnums=(0,))

        def _admit_seq(state, toks, table_row, slot):
            # prefill + paged-cache scatter fused into ONE dispatch;
            # returns the last-position logits for first-token sampling
            logits, cache = lm.prefill(params, cfg, {"tokens": toks})
            state = kv_cache.load_prefill(cfg, state, cache, slot,
                                          table_row, block_size)
            return logits[0, toks.shape[1] - 1], state

        self._admit_fn = jax.jit(_admit_seq, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # queue / scheduler
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 (the "
                f"first token is sampled from the prefill logits)")
        if len(req.prompt) + req.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new_tokens} exceeds "
                f"max_seq_len {self.max_seq_len}")
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def _now(self) -> float:
        """Seconds on the engine clock (fresh reading — timestamps must be
        taken AFTER the blocking device work they account for)."""
        return time.perf_counter() - self._t0

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _admit(self) -> None:
        """Move queued requests into free lanes while resources last."""
        while self._queue:
            slot_id = self._free_slot()
            if slot_id is None:
                return
            req = self._queue[0]
            need = -(-(len(req.prompt) + req.max_new_tokens)
                     // self.block_size)
            blocks = self.allocator.alloc(need)
            if blocks is None:
                return                      # pool exhausted; retry later
            self._queue.popleft()
            t_admit = self._now()
            row = np.full(self.max_blocks_per_seq, NULL_BLOCK, np.int32)
            row[:need] = blocks
            self._tables[slot_id] = row
            self._tables_dirty = True

            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            last, self.state = self._admit_fn(self.state, toks,
                                              jnp.asarray(row),
                                              jnp.int32(slot_id))
            if self.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                first = int(jax.random.categorical(
                    sub, last / self.temperature, -1))
            else:
                first = int(jnp.argmax(last, -1))
            # int() above blocks on the prefill, so TTFT includes it
            self._slots[slot_id] = _Slot(
                req=req, blocks=blocks, pos=len(req.prompt), pending=first,
                out=[first], t_admit=t_admit, t_first=self._now())
            self._maybe_finish(slot_id)

    def _maybe_finish(self, slot_id: int) -> None:
        s = self._slots[slot_id]
        done = (len(s.out) >= s.req.max_new_tokens
                or (s.req.eos_id is not None and s.out
                    and s.out[-1] == s.req.eos_id))
        if not done:
            return
        self._completions.append(Completion(
            rid=s.req.rid, prompt_len=len(s.req.prompt),
            tokens=np.asarray(s.out, np.int32), arrival=s.req.arrival,
            t_admit=s.t_admit, t_first_token=s.t_first,
            t_done=self._now()))
        self.allocator.free(s.blocks)
        self._tables[slot_id] = NULL_BLOCK
        self._tables_dirty = True
        self._slots[slot_id] = None

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def step(self) -> None:
        """One engine iteration: admit, then one batched decode step."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        tokens = np.zeros(self.num_slots, np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        for i in active:
            tokens[i] = self._slots[i].pending
            positions[i] = self._slots[i].pos
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
        else:
            sub = self._key          # unused by the greedy trace
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables)
            self._tables_dirty = False
        next_tok, self.state = self._decode_fn(
            self.state, jnp.asarray(tokens), jnp.asarray(positions),
            self._tables_dev, sub)
        next_tok = np.asarray(next_tok)
        self.steps += 1
        self.busy_lane_steps += len(active)
        for i in active:
            s = self._slots[i]
            s.pos += 1
            s.pending = int(next_tok[i])
            s.out.append(s.pending)
            self._maybe_finish(i)

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Drain `requests` (open loop: each enters the queue at its
        arrival offset on the engine clock) and return completions."""
        pending = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        self._t0 = time.perf_counter()
        self.steps = 0
        self.busy_lane_steps = 0
        while idx < len(pending) or self.has_work:
            now = self._now()
            while idx < len(pending) and pending[idx].arrival <= now:
                self.submit(pending[idx])
                idx += 1
            if not self.has_work:
                # idle until the next arrival
                time.sleep(min(pending[idx].arrival - now, 0.05))
                continue
            self.step()
        self.wall_time = self._now()
        done, self._completions = self._completions, []
        return done


# ----------------------------------------------------------------------------
# synthetic open-loop traffic + telemetry
# ----------------------------------------------------------------------------

def synthetic_requests(n: int, *, vocab_size: int, prompt_len: int = 64,
                       max_new: tuple = (8, 32), rate: float = float("inf"),
                       seed: int = 0) -> List[Request]:
    """Open-loop workload: Poisson arrivals at `rate` req/s (inf = all at
    t=0), random prompts, uniform generation lengths in `max_new`."""
    rng = np.random.default_rng(seed)
    if np.isinf(rate):
        arrivals = np.zeros(n)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    lo, hi = max_new
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab_size, prompt_len).astype(np.int32),
        max_new_tokens=int(rng.integers(lo, hi + 1)),
        arrival=float(arrivals[i])) for i in range(n)]


def summarize(completions: Sequence[Completion], wall: float,
              engine: Optional[ServingEngine] = None) -> Dict:
    """Throughput / latency telemetry over a finished run."""
    if not completions:
        stats = {"requests": 0, "generated_tokens": 0,
                 "wall_s": round(wall, 4), "tokens_per_s": 0.0}
        if engine is not None:
            stats["kv_cache_mb"] = round(engine.cache_bytes / 2**20, 2)
        return stats
    gen = sum(len(c.tokens) for c in completions)
    ttft = np.array([c.t_first_token - c.arrival for c in completions])
    lat = np.array([c.t_done - c.arrival for c in completions])
    per_tok = np.array([(c.t_done - c.t_first_token)
                        / max(len(c.tokens) - 1, 1) for c in completions])
    stats = {
        "requests": len(completions),
        "generated_tokens": gen,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(gen / max(wall, 1e-9), 2),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 2),
        "ttft_p99_ms": round(float(np.percentile(ttft, 99)) * 1e3, 2),
        "latency_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "latency_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "tpot_p50_ms": round(float(np.percentile(per_tok, 50)) * 1e3, 2),
    }
    if engine is not None:
        stats["kv_cache_mb"] = round(engine.cache_bytes / 2**20, 2)
        if engine.steps:
            stats["decode_steps"] = engine.steps
            stats["slot_occupancy"] = round(
                engine.busy_lane_steps / (engine.steps * engine.num_slots),
                3)
    return stats
