"""Continuous-batching serving engine: a thin facade over three layers.

The paper's tradeoff — hold a batch, amortize fixed costs over it, pay
synchronization only at coarse boundaries — applied to inference. The
engine composes:

  scheduler.Scheduler      queue, admission policy, request lifecycle,
                           per-request SamplingParams + unified stop
                           handling, eviction, copy-on-write
                           orchestration, draft proposers +
                           speculative accept/rollback, streaming
  block_manager.BlockAllocator
                           refcounted physical blocks + content-hash
                           prefix index (shared prompt blocks, COW)
  runner.ModelRunner       jitted bucketed batched prefill / decode /
                           multi-token verify dispatch, device block
                           tables + per-slot sampling-config arrays

Request lifecycle:
  queued -> admitted (prompt blocks bound, generation blocks reserved
  as a budget; cached prefix blocks shared by refcount; the prompt
  suffix prefilled in ONE batched jit dispatch together with other
  same-bucket prompts; first token sampled from the prefill logits
  with the request's own SamplingParams)
  -> decoding (one lane of the batched decode_step_paged per
  iteration — or, with speculate=K, of a batched K-token verify whose
  accepted prefix advances several tokens per dispatch and whose
  rejected suffix rolls back positions, recurrent state, and block
  claims) -> finished (max_new_tokens or a stop sequence) -> evicted
  (block refs dropped — shared prompt blocks stay warm for future
  hits).

Sampling is PER REQUEST (`Request.sampling = SamplingParams(...)`):
one engine step freely mixes greedy, sampled, and speculative-sampled
lanes in a single dispatch, and a request's realization is a pure
function of (its seed, its positions) — bit-identical whether it runs
alone or batched with anything else (see serving/sampling.py). Greedy
lanes stay bit-identical to `generate()` with speculation on or off;
sampled lanes under speculation preserve the target distribution via
Leviathan accept/reject with residual resampling.

`run()` blocks and returns completions; `stream()` is a generator of
incremental `StreamEvent`s (new tokens per request as they land, then
a done event carrying the Completion).

Prefix caching shares immutable prompt blocks across sequences and is
available for pure-attention block patterns; recurrent mixers (rwkv /
rec) carry dense per-slot state that is not block-structured, so the
engine auto-disables it there (requesting it explicitly raises).
Bucketed prefill works for every architecture: right-padded rows are
length-masked (see models/lm.py) so recurrent final states stay exact.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.block_manager import BlockAllocator
from repro.serving.kv_cache import ATTN_KINDS
from repro.serving.observability import NULL_OBS, Observability
from repro.serving.runner import ModelRunner
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (Completion, Request, Scheduler,
                                     SchedulerStats, StreamEvent)
from repro.serving.slo import SLOPolicy, SLOTracker


class ServingEngine:
    """Continuous-batching engine over a paged KV cache.

    num_slots          decode-batch width (lanes)
    block_size         tokens per physical KV block
    num_blocks         pool size; default sizes the pool to num_slots
                       sequences of max_seq_len (plus the null block)
    max_seq_len        hard per-sequence cap (prompt + generated)
    sampling           engine-default SamplingParams for requests that
                       carry none (per-request Request.sampling wins)
    prefix_cache       None = auto (on for pure-attention patterns)
    prefill_buckets    suffix-length buckets for batched prefill
                       (default: powers of two up to max_seq_len)
    prefill_max_batch  max prompts per prefill dispatch
    prefill_chunk      chunked-admission budget: a prompt whose suffix
                       exceeds the largest prefill bucket is admitted
                       chunk-by-chunk, one `prefill_chunk`-token chunk
                       per engine step, interleaved with decode so
                       running lanes aren't starved (None = default
                       2048, rounded to a bucket; 0 disables — such
                       prompts are then rejected at submit)
    speculate          max draft tokens per verify dispatch (0 = off);
                       composes with any SamplingParams — greedy lanes
                       use the argmax-compare accept rule (output
                       bit-identical to generate()), sampled lanes use
                       distribution-preserving accept/reject
    draft              draft proposer kind ('ngram': prompt lookup)
    ngram              longest n-gram the proposer tries to match
    max_logprobs       static top-k width compiled for the alternative-
                       logprob side output (SamplingParams.logprobs=k
                       must have k <= this)
    kv_dtype           KV pool precision: "fp16" (the activation dtype —
                       bit-identical default), "int8" or "fp8"
                       (quantized pools with per-(token, head) scale
                       side-tables — see serving/kv_cache.py)
    host_cache_blocks  capacity of the host-RAM spill tier (0 = off):
                       evicted cached blocks demote to a host LRU of
                       that many block payloads and revive on prefix
                       hit instead of being recomputed
    priority_aging     seconds of queue wait worth one priority class
                       at admission (starvation bound for low-priority
                       requests under priority scheduling; <= 0
                       disables aging — strict class order)
    slo_policy         declared SLO objectives (slo.SLOPolicy): builds
                       an SLOTracker fed TTFT / e2e latency / TPOT
                       observations (quantile sketches + burn rates)
    slo_tracker        pre-built SLOTracker to feed instead (how a
                       cluster shares ONE tracker across replicas —
                       burn rate is then cluster-wide); wins over
                       slo_policy
    slo_shed           enable deadline-aware admission: requests whose
                       `SamplingParams.deadline_ms` cannot be met are
                       shed (finish_reason "shed") and admission
                       orders by deadline slack within a class. OFF by
                       default — with it off, outputs are untouched by
                       the SLO layer (measurement only)

    temperature / seed are DEPRECATED engine-wide knobs, kept as a
    back-compat shim: they map to a default SamplingParams (with a
    DeprecationWarning). Prefer per-request Request.sampling.
    """

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 block_size: int = 16, max_seq_len: int = 512,
                 num_blocks: Optional[int] = None,
                 sampling: Optional[SamplingParams] = None,
                 temperature: Optional[float] = None,
                 seed: Optional[int] = None,
                 prefix_cache: Optional[bool] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 prefill_max_batch: int = 4,
                 prefill_chunk: Optional[int] = None, speculate: int = 0,
                 draft: str = "ngram", ngram: int = 3,
                 max_logprobs: int = 8, kv_dtype: str = "fp16",
                 host_cache_blocks: int = 0,
                 priority_aging: float = 2.0,
                 slo_policy: Optional[SLOPolicy] = None,
                 slo_tracker: Optional[SLOTracker] = None,
                 slo_shed: bool = False,
                 obs: Observability = NULL_OBS):
        if cfg.frontend != "none":
            raise NotImplementedError(
                "serving engine currently supports text LMs only")
        if temperature is not None or seed is not None:
            warnings.warn(
                "engine-level temperature=/seed= are deprecated: pass "
                "sampling=SamplingParams(...) for an engine default, or "
                "set Request.sampling per request",
                DeprecationWarning, stacklevel=2)
            if sampling is None:
                sampling = SamplingParams(temperature=temperature or 0.0,
                                          seed=seed or 0)
        self.default_sampling = sampling or SamplingParams()
        attn_only = all(k in ATTN_KINDS
                        for k in cfg.block_pattern + cfg.prefix_pattern)
        if prefix_cache and not attn_only:
            raise ValueError(
                "prefix caching requires a pure-attention block pattern "
                "(recurrent state is per-slot, not block-structured)")
        self.prefix_cache = attn_only if prefix_cache is None \
            else bool(prefix_cache)
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = -(-max_seq_len // block_size)
        self.max_seq_len = max_seq_len
        if num_blocks is None:
            num_blocks = 1 + num_slots * self.max_blocks_per_seq

        self.speculate = max(0, speculate)
        self.draft = draft
        self.kv_dtype = kv_dtype
        self.host_cache_blocks = max(0, int(host_cache_blocks))
        self.obs = obs or NULL_OBS
        if slo_tracker is not None:
            self.slo = slo_tracker
        elif slo_policy is not None:
            self.slo = SLOTracker(slo_policy)
        else:
            self.slo = None
        self.slo_shed = bool(slo_shed)
        self._g_burn_fast = self.obs.gauge("slo_burn_rate_fast_gauge")
        self._g_burn_slow = self.obs.gauge("slo_burn_rate_slow_gauge")
        self._t0 = time.perf_counter()  # engine clock origin (reset by run)
        # runner first: the allocator's host spill tier moves payloads
        # through the runner's fetch/upload callbacks
        self.runner = ModelRunner(
            params, cfg, num_slots=num_slots, block_size=block_size,
            num_blocks=num_blocks,
            max_blocks_per_seq=self.max_blocks_per_seq,
            prefill_buckets=prefill_buckets,
            prefill_max_batch=prefill_max_batch,
            prefill_chunk=prefill_chunk, speculate=self.speculate,
            max_logprobs=max_logprobs, kv_dtype=kv_dtype, obs=self.obs,
            now_fn=self._now)
        self.allocator = BlockAllocator(
            num_blocks, block_size=block_size, obs=self.obs,
            host_cache_blocks=self.host_cache_blocks,
            fetch_block=self.runner.fetch_block,
            store_blocks=self.runner.upload_blocks)
        self.scheduler = Scheduler(
            self.allocator, self.runner, num_slots=num_slots,
            block_size=block_size,
            max_blocks_per_seq=self.max_blocks_per_seq,
            max_seq_len=max_seq_len, prefix_cache=self.prefix_cache,
            now_fn=self._now, speculate=self.speculate, draft=draft,
            ngram=ngram, default_sampling=self.default_sampling,
            priority_aging_s=priority_aging, slo_tracker=self.slo,
            slo_shed=self.slo_shed, obs=self.obs)
        self.cache_bytes = self.runner.cache_bytes
        self.steps = 0                # decode+verify iterations executed
        self.busy_lane_steps = 0      # sum of active lanes over iterations

    # ------------------------------------------------------------------
    # facade
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def stats(self) -> SchedulerStats:
        """Structured occupancy snapshot (queue depth, slot occupancy,
        allocator free/cached block counts) — what a replica router
        reads to place load."""
        return self.scheduler.stats()

    def _now(self) -> float:
        """Seconds on the engine clock (fresh reading — timestamps must be
        taken AFTER the blocking device work they account for)."""
        return time.perf_counter() - self._t0

    def begin_run(self, t0: Optional[float] = None) -> None:
        """Reset the engine clock and per-run telemetry counters. `t0`
        (a time.perf_counter reading) lets a cluster router give every
        replica one shared clock origin so timestamps are comparable
        across replicas; None starts the clock now."""
        self._t0 = time.perf_counter() if t0 is None else t0
        self.steps = 0
        self.busy_lane_steps = 0
        self.scheduler.reset_stats()      # telemetry is per run
        self.runner.reset_stats()
        self.allocator.cache_evictions = 0
        self.allocator.host_demotions = 0
        self.allocator.host_revivals = 0
        self.obs.begin_run()
        if self.slo is not None:
            self.slo.reset()          # shared trackers reset idempotently
            if self.obs.enabled:
                self.obs.slo = self.slo   # metrics_dump emits v2 sections
        if self.obs.enabled:
            # static pool-capacity gauges (instruments reset per run)
            self.obs.gauge("kv_device_bytes_gauge").set(self.cache_bytes)
            self.obs.gauge("kv_host_bytes_gauge").set(
                self.host_cache_blocks * self.runner.block_bytes)

    def align_clock(self, t0: float) -> None:
        """Adopt a cluster clock origin WITHOUT resetting telemetry —
        what a replica activated mid-run needs (begin_run would wipe
        the cluster's shared metrics registry mid-flight)."""
        self._t0 = t0

    def reset_prefix_cache(self) -> None:
        """Drop cached prompt blocks (e.g. between benchmark runs)."""
        self.allocator.reset_prefix_cache()

    def step(self) -> None:
        """One engine iteration: admit, then one batched decode or
        verify step. With speculation on, lanes whose proposers drafted
        anything go through one multi-token verify dispatch (propose ->
        verify -> accept/rollback); when nothing was proposed the
        iteration falls back to the plain decode dispatch, so idle
        proposers cost nothing. A long prompt mid-chunked-admission
        advances by exactly one prefill chunk per iteration, BEFORE the
        decode/verify dispatch, so running lanes keep emitting tokens
        throughout a long admission instead of stalling behind it."""
        self.scheduler.admit()
        self.scheduler.prefill_step()
        if self.obs.enabled:
            # occupancy time series (sampled post-admission so queue
            # depth and slot occupancy reflect this step's batch)
            self.obs.sample_stats(self._now(), self.scheduler.stats())
        if self.slo is not None:
            # burn-rate tick on the run clock (records the run peaks
            # the bench gates on; gauges are no-ops with obs off)
            fast, slow = self.slo.tick(self._now())
            self._g_burn_fast.set(fast or 0.0)
            self._g_burn_slow.set(slow or 0.0)
        fr = self.obs.recorder
        if fr is not None:            # eviction-thrash detection
            fr.note_evictions(self._now(), self.allocator.cache_evictions)
        if self.speculate:
            vb = self.scheduler.prepare_verify()
            if vb is not None:
                tokens, positions, counts, active = vb
                emit, accept, lp, alt = self.runner.verify(
                    tokens, positions, counts)
                self.steps += 1
                self.busy_lane_steps += len(active)
                self.scheduler.consume_verify(active, emit, accept, lp,
                                              alt)
                return
        batch = self.scheduler.prepare_decode()
        if batch is None:
            return
        tokens, positions, active = batch
        next_tok, lp, alt = self.runner.decode(tokens, positions)
        self.steps += 1
        self.busy_lane_steps += len(active)
        self.scheduler.consume(active, next_tok, lp, alt)

    def _drive(self, requests: Sequence[Request]) -> Iterator[None]:
        """The engine loop as a generator (open loop: each request
        enters the queue at its arrival offset on the engine clock);
        yields after every step so `stream` can drain events."""
        pending = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        self.begin_run()
        while idx < len(pending) or self.has_work:
            now = self._now()
            while idx < len(pending) and pending[idx].arrival <= now:
                self.submit(pending[idx])
                idx += 1
            if not self.has_work:
                # idle until the next arrival
                time.sleep(min(pending[idx].arrival - now, 0.05))
                continue
            self.step()
            yield
        self.wall_time = self._now()

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Drain `requests` and return completions (blocking)."""
        for _ in self._drive(requests):
            pass
        done, self.scheduler.completions = self.scheduler.completions, []
        return done

    def stream(self, requests: Sequence[Request]) -> Iterator[StreamEvent]:
        """Drain `requests`, yielding incremental StreamEvents: new
        tokens per request as each engine step lands them (several at
        once under speculation), then a done event carrying the
        request's Completion. Equivalent token-for-token to `run()`.

        The generator must be consumed to exhaustion: abandoning it
        mid-stream leaves the undrained requests live in their slots
        (holding blocks), and a later `run()`/`stream()` on this engine
        will keep stepping them and fold their Completions into its own
        results — there is no per-request cancel today."""
        buf: List[StreamEvent] = []
        prev = self.scheduler.on_event
        self.scheduler.on_event = buf.append
        try:
            for _ in self._drive(requests):
                while buf:
                    yield buf.pop(0)
            while buf:
                yield buf.pop(0)
            self.scheduler.completions = []
        finally:
            self.scheduler.on_event = prev


# ----------------------------------------------------------------------------
# synthetic open-loop traffic + telemetry
# ----------------------------------------------------------------------------

def _sample_lengths(rng, spec: Union[int, Tuple[int, int]], n: int):
    """Fixed length (int) or uniform-inclusive mixed lengths (lo, hi)."""
    if isinstance(spec, (tuple, list)):
        lo, hi = spec
        return rng.integers(lo, hi + 1, n)
    return np.full(n, int(spec))


def _arrivals(rng, n: int, rate: float):
    if np.isinf(rate):
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate, n))


def _per_request(sampling: Optional[SamplingParams], i: int):
    """Stamp request i with its own PRNG stream (seed + i) so sampled
    workloads stay reproducible AND per-request independent."""
    if sampling is None:
        return None
    return dataclasses.replace(sampling, seed=sampling.seed + i)


def synthetic_requests(n: int, *, vocab_size: int,
                       prompt_len: Union[int, Tuple[int, int]] = 64,
                       max_new: tuple = (8, 32), rate: float = float("inf"),
                       sampling: Optional[SamplingParams] = None,
                       seed: int = 0) -> List[Request]:
    """Open-loop workload: Poisson arrivals at `rate` req/s (inf = all at
    t=0), random prompts, uniform generation lengths in `max_new`.
    `prompt_len` may be an int (fixed) or a (lo, hi) range (mixed-length
    traffic — exercises the prefill length buckets). `sampling` stamps
    every request with that config (per-request seeds derived as
    sampling.seed + i); None leaves requests greedy."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rng, n, rate)
    plens = _sample_lengths(rng, prompt_len, n)
    lo, hi = max_new
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab_size, int(plens[i])).astype(np.int32),
        max_new_tokens=int(rng.integers(lo, hi + 1)),
        arrival=float(arrivals[i]),
        sampling=_per_request(sampling, i)) for i in range(n)]


def shared_prefix_requests(n: int, *, vocab_size: int, prefix_len: int = 48,
                           suffix_len: Union[int, Tuple[int, int]] = (4, 16),
                           max_new: tuple = (8, 32), n_prefixes: int = 1,
                           rate: float = float("inf"),
                           sampling: Optional[SamplingParams] = None,
                           seed: int = 0) -> List[Request]:
    """Shared-prefix workload: every prompt is one of `n_prefixes` common
    system prompts of `prefix_len` tokens followed by a random per-request
    suffix — the canonical prefix-cache scenario (identical prompt-prefix
    blocks shared across sequences, copy-on-write at the divergence)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab_size, prefix_len).astype(np.int32)
                for _ in range(max(n_prefixes, 1))]
    arrivals = _arrivals(rng, n, rate)
    slens = _sample_lengths(rng, suffix_len, n)
    lo, hi = max_new
    out = []
    for i in range(n):
        suffix = rng.integers(0, vocab_size, int(slens[i])).astype(np.int32)
        out.append(Request(
            rid=i,
            prompt=np.concatenate([prefixes[i % len(prefixes)], suffix]),
            max_new_tokens=int(rng.integers(lo, hi + 1)),
            arrival=float(arrivals[i]),
            sampling=_per_request(sampling, i)))
    return out


def multi_tenant_requests(n: int, *, vocab_size: int, n_tenants: int = 4,
                          prefix_len: Union[int, Tuple[int, int]] = 48,
                          suffix_len: Union[int, Tuple[int, int]] = (4, 16),
                          max_new: tuple = (8, 32),
                          rate: float = float("inf"),
                          tenant_priorities: Optional[Sequence[int]] = None,
                          tenant_weights: Optional[Sequence[float]] = None,
                          sampling: Optional[SamplingParams] = None,
                          seed: int = 0) -> List[Request]:
    """Multi-tenant workload: `n_tenants` distinct shared system prompts
    (tenants), each request drawn to a random tenant so tenant traffic
    INTERLEAVES, followed by a random per-request suffix. `prefix_len`
    may be an int or a (lo, hi) range (per-tenant prompt lengths — lands
    tenants in different prefill buckets).

    This is the workload that separates prefix-affinity routing from
    round-robin: every tenant's prefix is cacheable, but only on
    replicas that already served that tenant — an affinity router pins
    each tenant to the replica holding its blocks, while round-robin
    re-prefills each tenant's prefix once per replica it touches.

    Per-tenant SLO mixes: `tenant_priorities[k]` stamps tenant k's
    requests with that scheduler priority class (an interactive tenant
    outranks — and may preempt — a batch tenant), and `tenant_weights`
    skews how much traffic each tenant sends. Both default to off, in
    which case the rng draw sequence is byte-identical to the
    pre-priority generator (committed bench records depend on it)."""
    rng = np.random.default_rng(seed)
    plens = _sample_lengths(rng, prefix_len, max(n_tenants, 1))
    prefixes = [rng.integers(0, vocab_size, int(p)).astype(np.int32)
                for p in plens]
    if tenant_weights is not None:
        w = np.asarray(tenant_weights, dtype=float)
        if len(w) != len(prefixes):
            raise ValueError("need one tenant_weights entry per tenant")
        tenants = rng.choice(len(prefixes), size=n, p=w / w.sum())
    else:
        tenants = rng.integers(0, len(prefixes), n)
    if tenant_priorities is not None and \
            len(tenant_priorities) != len(prefixes):
        raise ValueError("need one tenant_priorities entry per tenant")
    arrivals = _arrivals(rng, n, rate)
    slens = _sample_lengths(rng, suffix_len, n)
    lo, hi = max_new
    out = []
    for i in range(n):
        suffix = rng.integers(0, vocab_size, int(slens[i])).astype(np.int32)
        tenant = int(tenants[i])
        out.append(Request(
            rid=i,
            prompt=np.concatenate([prefixes[tenant], suffix]),
            max_new_tokens=int(rng.integers(lo, hi + 1)),
            arrival=float(arrivals[i]),
            priority=(int(tenant_priorities[tenant])
                      if tenant_priorities is not None else 0),
            sampling=_per_request(sampling, i)))
    return out


def bursty_requests(n: int, *, vocab_size: int, base_rate: float = 4.0,
                    burst_rate: float = 64.0, burst_every: float = 2.0,
                    burst_len: float = 0.25,
                    prompt_len: Union[int, Tuple[int, int]] = (8, 24),
                    max_new: tuple = (8, 32),
                    priorities: Sequence[int] = (0,),
                    priority_weights: Optional[Sequence[float]] = None,
                    sampling: Optional[SamplingParams] = None,
                    seed: int = 0) -> List[Request]:
    """Bursty (diurnal) workload: arrivals follow a two-state modulated
    Poisson process — every `burst_every` seconds the rate switches to
    `burst_rate` for `burst_len` seconds, then falls back to
    `base_rate`. The cycle starts IN a burst, so a queue piles up at
    t=0 and then drains into a sparse tail: exactly the shape that
    makes a fixed-size cluster pay p99 TTFT during the spike while
    sitting idle between spikes — the autoscaler's motivating traffic.

    Arrival times are drawn by exact inversion of the inhomogeneous
    Poisson integral (piecewise-constant rate), so the process is
    seeded and reproducible like every other generator here. Each
    request's priority class is drawn from `priorities` (uniformly, or
    by `priority_weights`) — mix classes to exercise preemption under
    burst pressure."""
    if base_rate <= 0 or burst_rate <= 0:
        raise ValueError("rates must be positive")
    rng = np.random.default_rng(seed)

    def _advance(t: float, e: float) -> float:
        # spend exponential mass `e` walking forward through the
        # piecewise-constant rate profile
        while True:
            phase = t % burst_every
            in_burst = phase < burst_len
            r = burst_rate if in_burst else base_rate
            edge = burst_len if in_burst else burst_every
            dt = edge - phase              # time left in this state
            if e <= r * dt:
                return t + e / r
            e -= r * dt
            t += dt

    arrivals = []
    t = 0.0
    for _ in range(n):
        t = _advance(t, rng.exponential(1.0))
        arrivals.append(t)
    if priority_weights is not None:
        w = np.asarray(priority_weights, dtype=float)
        if len(w) != len(priorities):
            raise ValueError("need one priority_weights entry per class")
        pidx = rng.choice(len(priorities), size=n, p=w / w.sum())
    else:
        pidx = rng.integers(0, len(priorities), n)
    plens = _sample_lengths(rng, prompt_len, n)
    lo, hi = max_new
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab_size, int(plens[i])).astype(np.int32),
        max_new_tokens=int(rng.integers(lo, hi + 1)),
        arrival=float(arrivals[i]),
        priority=int(priorities[int(pidx[i])]),
        sampling=_per_request(sampling, i)) for i in range(n)]


def diurnal_requests(n: int, *, vocab_size: int, rate_min: float = 1.0,
                     rate_max: float = 32.0, period: float = 8.0,
                     segments: int = 32,
                     prompt_len: Union[int, Tuple[int, int]] = (8, 24),
                     max_new: tuple = (8, 32),
                     priorities: Sequence[int] = (0,),
                     priority_weights: Optional[Sequence[float]] = None,
                     sampling: Optional[SamplingParams] = None,
                     seed: int = 0) -> List[Request]:
    """Diurnal workload: a seeded piecewise-sinusoidal rate profile —
    the smooth day/night traffic shape, compressed to a `period` an SLO
    autoscaler can ride within one run. The rate sweeps

        rate(t) = rate_min + (rate_max - rate_min)
                  * (1 - cos(2*pi*t / period)) / 2

    starting at the TROUGH (rate_min at t=0, peak at period/2), so a
    run opens calm, climbs into saturation, and relaxes again —
    exercising scale-out on the rising edge and scale-in on the falling
    one, without bursty_requests' step discontinuities.

    The sinusoid is discretized into `segments` piecewise-constant
    steps per period (rate = the segment-midpoint value) and arrivals
    are drawn by the same exact inversion of the inhomogeneous Poisson
    integral bursty_requests uses — seeded and reproducible. Priority
    classes mix exactly as there."""
    if rate_min <= 0 or rate_max < rate_min:
        raise ValueError("need 0 < rate_min <= rate_max")
    if period <= 0 or segments < 2:
        raise ValueError("need period > 0 and segments >= 2")
    rng = np.random.default_rng(seed)
    seg = period / segments
    rates = [rate_min + (rate_max - rate_min)
             * (1.0 - math.cos(2.0 * math.pi * (k + 0.5) / segments))
             / 2.0 for k in range(segments)]

    def _advance(t: float, e: float) -> float:
        # spend exponential mass `e` walking forward through the
        # piecewise-constant discretization (segment-index walk, so
        # float edges can't strand t at a boundary)
        k = int(t // seg)
        while True:
            r = rates[k % segments]
            dt = (k + 1) * seg - t
            if dt > 0 and e <= r * dt:
                return t + e / r
            e -= r * max(dt, 0.0)
            t = (k + 1) * seg
            k += 1

    arrivals = []
    t = 0.0
    for _ in range(n):
        t = _advance(t, rng.exponential(1.0))
        arrivals.append(t)
    if priority_weights is not None:
        w = np.asarray(priority_weights, dtype=float)
        if len(w) != len(priorities):
            raise ValueError("need one priority_weights entry per class")
        pidx = rng.choice(len(priorities), size=n, p=w / w.sum())
    else:
        pidx = rng.integers(0, len(priorities), n)
    plens = _sample_lengths(rng, prompt_len, n)
    lo, hi = max_new
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab_size, int(plens[i])).astype(np.int32),
        max_new_tokens=int(rng.integers(lo, hi + 1)),
        arrival=float(arrivals[i]),
        priority=int(priorities[int(pidx[i])]),
        sampling=_per_request(sampling, i)) for i in range(n)]


def long_document_requests(n: int, *, vocab_size: int,
                           prompt_len: Union[int, Tuple[int, int]] = 4096,
                           max_new: tuple = (4, 16),
                           rate: float = float("inf"),
                           sampling: Optional[SamplingParams] = None,
                           seed: int = 0) -> List[Request]:
    """Long-document workload: few requests, each carrying a prompt far
    longer than any prefill bucket — summarization / document-QA style
    traffic. This is the workload chunked admission exists for: each
    prompt is split into fixed-budget chunks across successive engine
    steps (peak score materialization stays bounded by the chunk
    budget) while any already-running lanes keep decoding between
    chunks. Prompts are random tokens (content-free, like the other
    synthetic workloads); `prompt_len` may be an int or (lo, hi)."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rng, n, rate)
    plens = _sample_lengths(rng, prompt_len, n)
    lo, hi = max_new
    return [Request(
        rid=i,
        prompt=rng.integers(0, vocab_size, int(plens[i])).astype(np.int32),
        max_new_tokens=int(rng.integers(lo, hi + 1)),
        arrival=float(arrivals[i]),
        sampling=_per_request(sampling, i)) for i in range(n)]


def repetitive_requests(n: int, *, vocab_size: int, period: int = 6,
                        prompt_len: Union[int, Tuple[int, int]] = 48,
                        max_new: tuple = (16, 32),
                        rate: float = float("inf"),
                        sampling: Optional[SamplingParams] = None,
                        seed: int = 0) -> List[Request]:
    """Repetitive-text workload: each prompt tiles a short random
    pattern of `period` tokens — the canonical n-gram (prompt-lookup)
    speculation scenario: the proposer finds the recurring n-gram in
    the prompt/generated history and drafts its continuation."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rng, n, rate)
    plens = _sample_lengths(rng, prompt_len, n)
    lo, hi = max_new
    out = []
    for i in range(n):
        pattern = rng.integers(0, vocab_size, period).astype(np.int32)
        reps = -(-int(plens[i]) // period)
        out.append(Request(
            rid=i,
            prompt=np.tile(pattern, reps)[:int(plens[i])],
            max_new_tokens=int(rng.integers(lo, hi + 1)),
            arrival=float(arrivals[i]),
            sampling=_per_request(sampling, i)))
    return out


def _rate(count: float, wall: float) -> float:
    """count/wall as a rate, well-defined for degenerate runs: a zero or
    negative wall clock (e.g. a run whose work all landed inside one
    clock tick) reports 0.0 instead of a nonsense near-infinite rate."""
    return round(count / wall, 2) if wall > 0 else 0.0


def summarize(completions: Sequence[Completion], wall: float,
              engine: Optional[ServingEngine] = None) -> Dict:
    """Throughput / latency telemetry over a finished run. Well-defined
    for degenerate inputs: empty completion lists, a single completion
    (percentiles collapse to that value), and zero wall clock. Shed
    requests (finish_reason == "shed") produced no tokens and carry a
    synthetic t_first_token, so they are excluded from the latency
    percentiles and counted separately."""
    shed = [c for c in completions if c.finish_reason == "shed"]
    if shed:
        # only rebind when sheds happened: records from shed-free runs
        # stay byte-identical to pre-SLO ones
        completions = [c for c in completions
                       if c.finish_reason != "shed"]
    if not completions:
        stats = {"requests": 0, "generated_tokens": 0,
                 "wall_s": round(wall, 4), "tokens_per_s": 0.0}
        if shed:
            stats["shed_requests"] = len(shed)
        if engine is not None:
            stats["kv_cache_mb"] = round(engine.cache_bytes / 2**20, 2)
        return stats
    gen = sum(len(c.tokens) for c in completions)
    ttft = np.array([c.t_first_token - c.arrival for c in completions])
    lat = np.array([c.t_done - c.arrival for c in completions])
    per_tok = np.array([(c.t_done - c.t_first_token)
                        / max(len(c.tokens) - 1, 1) for c in completions])
    stats = {
        "requests": len(completions),
        "generated_tokens": gen,
        "wall_s": round(wall, 4),
        "tokens_per_s": _rate(gen, wall),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 2),
        "ttft_p95_ms": round(float(np.percentile(ttft, 95)) * 1e3, 2),
        "ttft_p99_ms": round(float(np.percentile(ttft, 99)) * 1e3, 2),
        "latency_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "latency_p95_ms": round(float(np.percentile(lat, 95)) * 1e3, 2),
        "latency_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "tpot_p50_ms": round(float(np.percentile(per_tok, 50)) * 1e3, 2),
        "tpot_p95_ms": round(float(np.percentile(per_tok, 95)) * 1e3, 2),
        "tpot_p99_ms": round(float(np.percentile(per_tok, 99)) * 1e3, 2),
    }
    if engine is not None:
        stats["kv_cache_mb"] = round(engine.cache_bytes / 2**20, 2)
        if engine.steps:
            stats["decode_steps"] = engine.steps
            stats["slot_occupancy"] = round(
                engine.busy_lane_steps / (engine.steps * engine.num_slots),
                3)
        sched, runner = engine.scheduler, engine.runner
        if sched.sampled_requests:
            # greedy-only records stay byte-identical to pre-sampling
            # runs: the block appears only when a request sampled
            stats["sampling"] = {
                "sampled_requests": sched.sampled_requests,
                "greedy_requests": sched.greedy_requests,
                "sampled_dispatches": runner.sampled_dispatches,
                "stop_finishes": sum(
                    1 for c in completions if c.finish_reason == "stop"),
            }
        stats["prefill"] = {
            "dispatches": runner.prefill_dispatches,
            "shapes": len(runner.prefill_shapes),
            "buckets": (len(runner.prefill_buckets)
                        * len(runner.width_buckets)),
            "prompt_tokens": sched.prompt_tokens,
            "computed_tokens": runner.prefill_computed_tokens,
            "cached_tokens": sched.cached_prompt_tokens,
            "padded_tokens": runner.prefill_padded_tokens,
            # analytic peak score-tile bytes of the largest prefill
            # dispatch (the memory chunked admission bounds): with the
            # streamed attention path this stays flat past attn_chunk
            # no matter how long the prompt is
            "chunk_budget": runner.prefill_chunk,
            "peak_score_bytes": runner.prefill_peak_score_bytes,
        }
        snap = engine.stats()             # structured occupancy accessor
        stats["prefix_cache"] = {
            "enabled": engine.prefix_cache,
            "hit_requests": sched.prefix_hit_requests,
            "block_copies": runner.block_copies,
            "evictions": engine.allocator.cache_evictions,
            # blocks still holding reusable prefix KV after the run
            "warm_blocks": snap.cached_blocks,
        }
        stats["kv"] = {
            "dtype": engine.kv_dtype,
            "device_pool_bytes": engine.cache_bytes,
            "host_cache_blocks": engine.host_cache_blocks,
            "host_pool_bytes": (engine.host_cache_blocks
                                * engine.runner.block_bytes),
            "spilled_blocks": snap.spilled_blocks,
            "host_demotions": engine.allocator.host_demotions,
            "host_revivals": engine.allocator.host_revivals,
        }
        if engine.speculate:
            dispatches = engine.steps      # decode + verify iterations
            stats["speculation"] = {
                "enabled": True,
                "k": engine.speculate,
                "draft": engine.draft,
                "verify_dispatches": runner.verify_dispatches,
                "verify_shapes": len(runner.verify_shapes),
                "verify_buckets": len(runner.verify_buckets),
                # chain slots dispatched vs true chain tokens: the gap
                # is bucket-padding waste (verify compute scales with
                # it — the term that erodes the spec win at high slots)
                "verify_chain_tokens": runner.verify_chain_tokens,
                "verify_padded_tokens": runner.verify_padded_tokens,
                "proposed_tokens": sched.proposed_tokens,
                "accepted_tokens": sched.accepted_tokens,
                "acceptance_rate": round(
                    sched.accepted_tokens / max(sched.proposed_tokens, 1),
                    3),
                # each request's first token comes from its prefill
                # dispatch, not a decode/verify one — exclude it
                "tokens_per_dispatch": round(
                    max(gen - len(completions), 0) / max(dispatches, 1),
                    3),
            }
        if getattr(engine, "slo", None) is not None:
            stats["slo"] = engine.slo.snapshot()
            stats["slo"]["shed_requests"] = sched.shed_requests
            stats["slo"]["deferrals"] = sched.deferrals
    if shed:
        stats["shed_requests"] = len(shed)
    return stats
