"""Serving observability: metrics registry, request-lifecycle tracing,
and exporters (Chrome/Perfetto trace_event JSON + metrics-dump JSON).

The telemetry layer every serving component publishes into (kernels'
dispatch records come via the runner; scheduler, block manager, engine,
replica, and router each have their own instruments). The source
paper's tradeoff — communication vs memory vs computation — is only
navigable with measurements; this module records the signals the
control loops above the engine (SLO autoscaling, adaptive speculation
length) will steer by.

Four pieces:

  * `MetricsRegistry` — labeled counters, gauges, and fixed-bucket
    histograms (e.g. `scheduler_admitted_total{replica=0}`,
    `blocks_cached_gauge`, `verify_accept_len_hist{slot=3}`). Layers
    resolve their instruments ONCE at construction and call
    `inc`/`set`/`observe` on the hot path; the registry also holds the
    periodic `SchedulerStats`-derived time series (`series`) that an
    autoscaler would consume.
  * `Observability` — the recorder handle threaded through the stack.
    Collects trace spans on the SHARED engine/cluster clock: per-slot
    request-lifecycle spans (queued -> routed -> admitted -> prefill ->
    decode -> done), per-dispatch step records (kind, batch, bucket,
    emitted tokens, prefix-cache hits, accept lengths, and a
    `first_dispatch` flag so jit-compile stalls are attributable
    separately from steady-state steps), and async queue spans.
    `scoped(replica)` returns a view sharing all storage but stamping a
    replica label/track id — how a cluster's replicas publish into one
    recorder.
  * exporters — `to_perfetto()` renders the trace as Chrome
    `trace_event` JSON (one process per replica, one thread track per
    slot plus a `dispatch` track; open in https://ui.perfetto.dev),
    `metrics_dump()` renders the registry as a schema-versioned JSON
    document, and `validate_trace_events` / `validate_metrics_dump`
    check both formats (the CI gate).
  * `FlightRecorder` — an always-on bounded ring buffer of the most
    recent trace events (steady-state cost: one deque append per
    event, no export, no device sync) that dumps a schema-valid
    Perfetto trace when an anomaly fires — a TTFT-objective breach, a
    preemption storm, or eviction thrash — or on demand. The black box
    for tail-latency forensics: when something goes wrong you get the
    last `capacity` events leading up to it without having paid for
    full tracing all along.

The default recorder is `NULL_OBS`: every method is a no-op and
`enabled` is False, so layers guard their bookkeeping behind one
attribute check and the off path costs nothing. Recording never
touches device dispatch — with observability on, engine outputs stay
bit-identical to the recorder-off run (gated in serving_bench and
tests/test_observability.py).
"""
from __future__ import annotations

import copy
import json
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# trace_event thread id of the per-replica dispatch track (slot tracks
# use tid == slot index; any real slot count stays far below this)
DISPATCH_TID = 1000
# thread id of the flight-recorder anomaly track
FLIGHT_TID = 95

# current metrics-dump schema (v2 added the optional `sketches` and
# `slo` sections for the SLO layer's quantile sketches / burn-rate
# accounting); v1 documents remain valid
METRICS_SCHEMA = "repro.serving.metrics/v2"
METRICS_SCHEMAS = ("repro.serving.metrics/v1", METRICS_SCHEMA)
TRACE_SCHEMA = "repro.serving.trace_event/v1"


# ----------------------------------------------------------------------------
# instruments
# ----------------------------------------------------------------------------

class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written value (occupancy, rates)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram: counts[i] counts observations <=
    bounds[i]; counts[-1] is the overflow bucket (> bounds[-1])."""

    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(float(b) for b in bounds)
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing and non-empty: {bounds!r}")
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0


class _NullInstrument:
    """No-op counter/gauge/histogram — what NULL_OBS hands out so hot
    paths can hold one instrument reference unconditionally."""

    __slots__ = ()
    value = 0
    counts: List[int] = []
    total = 0.0
    count = 0
    mean = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(labels.items()))


class MetricsRegistry:
    """Labeled instruments + the stats time series. Instruments are
    keyed (name, sorted labels); resolving the same key returns the
    same object, so layers can cache references at construction and
    `reset()` (per run) zeroes values IN PLACE without invalidating
    them."""

    def __init__(self):
        self._counters: Dict[tuple, Counter] = {}
        self._gauges: Dict[tuple, Gauge] = {}
        self._histograms: Dict[tuple, Histogram] = {}
        self.series: List[Dict[str, Any]] = []

    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        if key not in self._counters:
            self._counters[key] = Counter()
        return self._counters[key]

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        if key not in self._gauges:
            self._gauges[key] = Gauge()
        return self._gauges[key]

    def histogram(self, name: str, bounds: Sequence[float],
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        if key not in self._histograms:
            self._histograms[key] = Histogram(bounds)
        return self._histograms[key]

    def total(self, name: str) -> int:
        """Sum of a counter across every label set (e.g. all replicas)."""
        return sum(c.value for (n, _), c in self._counters.items()
                   if n == name)

    def gauges_named(self, name: str) -> Dict[tuple, float]:
        return {k[1]: g.value for k, g in self._gauges.items()
                if k[0] == name}

    def histograms_named(self, name: str) -> Dict[tuple, Histogram]:
        return {k[1]: h for k, h in self._histograms.items()
                if k[0] == name}

    def reset(self) -> None:
        """Zero every instrument in place and drop the series (per-run
        telemetry); cached instrument references stay valid."""
        for group in (self._counters, self._gauges, self._histograms):
            for inst in group.values():
                inst.reset()
        self.series.clear()

    def to_dict(self) -> Dict[str, Any]:
        def rows(group, extra):
            out = []
            for (name, labels), inst in sorted(group.items()):
                row = {"name": name, "labels": dict(labels)}
                row.update(extra(inst))
                out.append(row)
            return out

        return {
            "counters": rows(self._counters,
                             lambda c: {"value": c.value}),
            "gauges": rows(self._gauges, lambda g: {"value": g.value}),
            "histograms": rows(self._histograms, lambda h: {
                "bounds": list(h.bounds), "counts": list(h.counts),
                "sum": h.total, "count": h.count}),
            "series": list(self.series),
        }


# ----------------------------------------------------------------------------
# the flight recorder
# ----------------------------------------------------------------------------

class FlightRecorder:
    """Always-on bounded ring of recent trace events with anomaly-
    triggered dumps — the serving stack's black box.

    Attach one via `Observability(recorder=...)`: every span / instant
    / async event the recorder handle sees is ALSO appended to the ring
    (same dict objects, so later `annotate_step` mutations are visible
    in the dump), and the ring's `deque(maxlen=capacity)` keeps memory
    bounded no matter how long the run is. Steady-state cost is one
    append per event — no export, no serialization, no device sync.

    Anomaly triggers, each recording an entry in `anomalies`, an
    instant on the FLIGHT_TID track, and (when `dump_path` is set and
    the rate limit allows) a schema-valid Perfetto dump of the ring:

      * `breach()` — called by the scheduler when a request's TTFT (or
        e2e latency) lands past its SLO objective
      * preemption storm — `note_preempt()` saw `preempt_storm`
        preemptions inside `window_s`
      * eviction thrash — `note_evictions()` saw `evict_thrash`
        cache evictions inside `window_s`

    Detector state rides the run clock (deterministic, no wall time);
    `min_dump_interval_s` keeps a sustained incident from rewriting the
    dump file every event.
    """

    def __init__(self, capacity: int = 4096, *,
                 dump_path: Optional[str] = None,
                 preempt_storm: int = 8, evict_thrash: int = 64,
                 window_s: float = 1.0,
                 min_dump_interval_s: float = 0.5):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.dump_path = dump_path
        self.preempt_storm = int(preempt_storm)
        self.evict_thrash = int(evict_thrash)
        self.window_s = float(window_s)
        self.min_dump_interval_s = float(min_dump_interval_s)
        self.ring: deque = deque(maxlen=self.capacity)
        self.anomalies: deque = deque(maxlen=256)
        self.appended = 0       # over all time; dropped = appended - len(ring)
        self.dumps = 0
        self._preempts: deque = deque()         # preemption timestamps
        self._evict_events: deque = deque()     # (t, delta) eviction rows
        self._evict_last = 0
        self._last_dump = float("-inf")

    # -- the hot path ----------------------------------------------------

    def append(self, kind: str, rec: Dict[str, Any]) -> None:
        """Ring append (kind is "span" / "instant" / "async"); the ONLY
        per-event cost of an attached recorder."""
        self.ring.append((kind, rec))
        self.appended += 1

    # -- anomaly triggers ------------------------------------------------

    def breach(self, t: float, reason: str, **args) -> None:
        """Record an anomaly (and dump the ring, rate-limited)."""
        self.anomalies.append({"t": t, "reason": reason, "args": args})
        self.append("instant", {"pid": 0, "tid": FLIGHT_TID,
                                "name": f"anomaly:{reason}",
                                "cat": "flight", "t": t, "args": args})
        if self.dump_path is not None \
                and t - self._last_dump >= self.min_dump_interval_s:
            self._last_dump = t
            self.dump(self.dump_path)

    def note_preempt(self, t: float) -> None:
        """Feed from the scheduler's preempt path: `preempt_storm`
        preemptions inside `window_s` is an anomaly."""
        self._preempts.append(t)
        while self._preempts and self._preempts[0] < t - self.window_s:
            self._preempts.popleft()
        if len(self._preempts) >= self.preempt_storm:
            n = len(self._preempts)
            self._preempts.clear()      # re-arm, don't re-fire per event
            self.breach(t, "preempt_storm", preemptions=n,
                        window_s=self.window_s)

    def note_evictions(self, t: float, total: int) -> None:
        """Feed from the engine step loop with the allocator's
        cumulative eviction counter; `evict_thrash` evictions inside
        `window_s` is an anomaly."""
        delta = total - self._evict_last
        self._evict_last = total
        if delta > 0:
            self._evict_events.append((t, delta))
        while self._evict_events \
                and self._evict_events[0][0] < t - self.window_s:
            self._evict_events.popleft()
        recent = sum(d for _, d in self._evict_events)
        if recent >= self.evict_thrash:
            self._evict_events.clear()  # re-arm
            self.breach(t, "eviction_thrash", evictions=recent,
                        window_s=self.window_s)

    # -- export ----------------------------------------------------------

    def to_perfetto(self) -> Dict[str, Any]:
        """The ring as a schema-valid Perfetto trace_event document
        (same renderer as the full-trace exporter), with a
        `flight_recorder` summary in otherData."""
        spans = [r for k, r in self.ring if k == "span"]
        instants = [r for k, r in self.ring if k == "instant"]
        asyncs = [r for k, r in self.ring if k == "async"]
        return _render_trace(spans, instants, asyncs, other={
            "flight_recorder": {
                "capacity": self.capacity,
                "events": len(self.ring),
                "dropped": self.appended - len(self.ring),
                "anomalies": list(self.anomalies),
            }})

    def dump(self, path: Optional[str] = None) -> Dict[str, Any]:
        doc = self.to_perfetto()
        path = path if path is not None else self.dump_path
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        self.dumps += 1
        return doc

    def reset(self) -> None:
        self.ring.clear()
        self.anomalies.clear()
        self.appended = 0
        self.dumps = 0
        self._preempts.clear()
        self._evict_events.clear()
        self._evict_last = 0
        self._last_dump = float("-inf")


# ----------------------------------------------------------------------------
# the recorder handle
# ----------------------------------------------------------------------------

class Observability:
    """Recorder threaded through every serving layer. One instance (or
    a `scoped(replica)` view of it) is shared by a whole engine stack;
    a cluster shares one root across all replicas so every span sits on
    one clock and every instrument carries its replica label.

    sample_interval   minimum seconds between SchedulerStats time-series
                      samples (0 = record every engine step).
    recorder          optional `FlightRecorder`: every span / instant /
                      async event is also ring-appended (shared dict
                      objects — cheap, bounded, dump-on-anomaly).
    """

    enabled = True

    def __init__(self, *, sample_interval: float = 0.05,
                 recorder: Optional[FlightRecorder] = None):
        self.registry = MetricsRegistry()
        self.sample_interval = float(sample_interval)
        self.replica = 0
        self.recorder = recorder
        self.slo = None         # optional SLOTracker (set by engine/serve)
        # trace storage (shared across scoped views)
        self.spans: List[Dict[str, Any]] = []     # complete spans
        self.instants: List[Dict[str, Any]] = []  # point events
        self.asyncs: List[Dict[str, Any]] = []    # queue-phase spans
        # mutable cells shared by every scoped view
        self._last_sample = [None]                # [Optional[float]]
        self._last_step: List[Optional[Dict[str, Any]]] = [None]

    # -- scoping ---------------------------------------------------------

    def scoped(self, replica: int) -> "Observability":
        """A view for one replica: shares the registry and all trace
        storage, stamps `replica` on tracks and instrument labels."""
        view = copy.copy(self)
        view.replica = replica
        return view

    def _labels(self, labels: Dict[str, Any]) -> Dict[str, Any]:
        if self.replica:
            labels.setdefault("replica", self.replica)
        return labels

    # -- instruments (replica label folded in) ---------------------------

    def counter(self, name: str, **labels) -> Counter:
        return self.registry.counter(name, **self._labels(labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self.registry.gauge(name, **self._labels(labels))

    def histogram(self, name: str, bounds: Sequence[float],
                  **labels) -> Histogram:
        return self.registry.histogram(name, bounds,
                                       **self._labels(labels))

    # -- trace spans -----------------------------------------------------

    def span(self, tid: int, name: str, cat: str, t0: float, t1: float,
             **args) -> Dict[str, Any]:
        rec = {"pid": self.replica, "tid": tid, "name": name, "cat": cat,
               "t0": t0, "t1": t1, "args": args}
        self.spans.append(rec)
        if self.recorder is not None:
            self.recorder.append("span", rec)
        return rec

    def instant(self, tid: int, name: str, cat: str, t: float,
                **args) -> None:
        rec = {"pid": self.replica, "tid": tid, "name": name, "cat": cat,
               "t": t, "args": args}
        self.instants.append(rec)
        if self.recorder is not None:
            self.recorder.append("instant", rec)

    def async_span(self, name: str, cat: str, aid: int, t0: float,
                   t1: float, **args) -> None:
        """A span that may overlap others (queue residency): rendered as
        Perfetto async b/e pairs keyed by `aid`."""
        rec = {"pid": self.replica, "name": name, "cat": cat,
               "id": aid, "t0": t0, "t1": t1, "args": args}
        self.asyncs.append(rec)
        if self.recorder is not None:
            self.recorder.append("async", rec)

    # -- dispatch step records -------------------------------------------

    def step(self, kind: str, t0: float, t1: float,
             **args) -> Dict[str, Any]:
        """One device dispatch (prefill / decode / verify) as a span on
        this replica's dispatch track. The record is kept open for
        `annotate_step` — the scheduler adds what the runner cannot know
        (emitted token counts, accept lengths)."""
        rec = self.span(DISPATCH_TID, kind, "dispatch", t0, t1, **args)
        self._last_step[0] = rec
        return rec

    def annotate_step(self, **args) -> None:
        rec = self._last_step[0]
        if rec is not None:
            rec["args"].update(args)

    # -- SchedulerStats time series --------------------------------------

    def sample_stats(self, t: float, stats) -> None:
        """Record occupancy gauges from a SchedulerStats snapshot and,
        subject to `sample_interval` throttling, append a time-series
        sample — the feed an SLO autoscaler consumes."""
        self.gauge("queue_depth_gauge").set(stats.queue_depth)
        self.gauge("active_slots_gauge").set(stats.active_slots)
        self.gauge("blocks_free_gauge").set(stats.free_blocks)
        self.gauge("blocks_cached_gauge").set(stats.cached_blocks)
        self.gauge("blocks_reserved_gauge").set(stats.reserved_blocks)
        spilled = getattr(stats, "spilled_blocks", 0)
        self.gauge("blocks_spilled_gauge").set(spilled)
        preempted = getattr(stats, "preempted", 0)
        self.gauge("preempted_gauge").set(preempted)
        last = self._last_sample[0]
        if last is not None and t - last < self.sample_interval:
            return
        self._last_sample[0] = t
        self.registry.series.append({
            "t": t, "replica": self.replica,
            "queue_depth": stats.queue_depth,
            "active_slots": stats.active_slots,
            "free_slots": stats.free_slots,
            "free_blocks": stats.free_blocks,
            "cached_blocks": stats.cached_blocks,
            "reserved_blocks": stats.reserved_blocks,
            "spilled_blocks": spilled,
            "preempted": preempted,
        })

    # -- lifecycle -------------------------------------------------------

    def begin_run(self) -> None:
        """Per-run reset (mirrors the engine's telemetry semantics):
        drop trace data and zero instruments, keeping instrument
        references valid. Shared storage resets once even when every
        replica's begin_run calls it."""
        self.registry.reset()
        self.spans.clear()
        self.instants.clear()
        self.asyncs.clear()
        self._last_sample[0] = None
        self._last_step[0] = None
        if self.recorder is not None:
            self.recorder.reset()


class _NullObservability(Observability):
    """The zero-cost default: `enabled` is False (layers skip their
    bookkeeping) and every method is a no-op, so an unguarded call
    costs one dynamic dispatch and records nothing."""

    enabled = False
    recorder = None
    slo = None

    def __init__(self):  # no storage at all
        pass

    def scoped(self, replica: int) -> "Observability":
        return self

    def counter(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, bounds: Sequence[float], **labels):
        return _NULL_INSTRUMENT

    def span(self, *a, **k):
        return {}

    def instant(self, *a, **k):
        pass

    def async_span(self, *a, **k):
        pass

    def step(self, *a, **k):
        return {}

    def annotate_step(self, **k):
        pass

    def sample_stats(self, *a, **k):
        pass

    def begin_run(self) -> None:
        pass


NULL_OBS = _NullObservability()


# ----------------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------------

def _us(t: float) -> float:
    return round(t * 1e6, 3)


def _track_name(tid: int) -> str:
    if tid == DISPATCH_TID:
        return "dispatch"
    if tid == FLIGHT_TID:
        return "flight-recorder"
    return f"slot {tid}"


def _render_trace(spans: Sequence[Dict[str, Any]],
                  instants: Sequence[Dict[str, Any]],
                  asyncs: Sequence[Dict[str, Any]],
                  other: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Render span/instant/async records as a Chrome/Perfetto
    `trace_event` document: one process per replica (pid), one thread
    per slot track plus the dispatch track (tid), complete ("X") spans
    for slot residency / lifecycle phases / dispatches, async ("b"/"e")
    spans for queue residency, and metadata naming every track.
    Timestamps are microseconds on the shared run clock. Shared by the
    full-trace exporter and the flight recorder's ring dumps."""
    events: List[Dict[str, Any]] = []
    tracks = set()
    for s in spans:
        tracks.add((s["pid"], s["tid"]))
        events.append({"name": s["name"], "cat": s["cat"], "ph": "X",
                       "ts": _us(s["t0"]),
                       "dur": max(_us(s["t1"]) - _us(s["t0"]), 0.0),
                       "pid": s["pid"], "tid": s["tid"],
                       "args": s["args"]})
    for i in instants:
        tracks.add((i["pid"], i["tid"]))
        events.append({"name": i["name"], "cat": i["cat"], "ph": "i",
                       "ts": _us(i["t"]), "s": "t", "pid": i["pid"],
                       "tid": i["tid"], "args": i["args"]})
    for a in asyncs:
        base = {"name": a["name"], "cat": a["cat"],
                "id": str(a["id"]), "pid": a["pid"], "tid": 0}
        events.append({**base, "ph": "b", "ts": _us(a["t0"]),
                       "args": a["args"]})
        events.append({**base, "ph": "e", "ts": _us(a["t1"])})
    for pid in sorted({p for p, _ in tracks} | {a["pid"]
                                                for a in asyncs}):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"replica {pid}"}})
    for pid, tid in sorted(tracks):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": _track_name(tid)}})
    other_data = {"schema": TRACE_SCHEMA}
    if other:
        other_data.update(other)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other_data}


def to_perfetto(obs: Observability) -> Dict[str, Any]:
    """The full recorded trace as a Perfetto document (see
    `_render_trace` for the layout)."""
    return _render_trace(obs.spans, obs.instants, obs.asyncs)


def metrics_dump(obs: Observability) -> Dict[str, Any]:
    """The registry (plus time series) as a schema-versioned document;
    with an SLOTracker attached (`obs.slo`), also the per-(metric,
    class) quantile sketches and the SLO summary (v2 sections)."""
    doc = {"schema": METRICS_SCHEMA}
    doc.update(obs.registry.to_dict())
    slo = getattr(obs, "slo", None)
    if slo is not None:
        doc["sketches"] = slo.sketch_rows()
        doc["slo"] = slo.snapshot()
    return doc


def export_trace(obs: Observability, path: str) -> Dict[str, Any]:
    doc = to_perfetto(obs)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def export_metrics(obs: Observability, path: str) -> Dict[str, Any]:
    doc = metrics_dump(obs)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


# ----------------------------------------------------------------------------
# schema validation (the CI gate)
# ----------------------------------------------------------------------------

def validate_trace_events(doc: Any) -> List[str]:
    """Errors that would make `doc` invalid Chrome trace_event JSON
    (empty list = loads in Perfetto). Checks the envelope, per-phase
    required fields, and numeric/orderable timestamps."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a traceEvents list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    open_async: Dict[tuple, int] = {}
    for n, ev in enumerate(events):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errs.append(f"{where}: missing ph")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errs.append(f"{where}: missing integer {key}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: ph={ph} needs a non-negative ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event needs dur >= 0")
        elif ph in ("b", "e"):
            if not isinstance(ev.get("id"), str):
                errs.append(f"{where}: async event needs a string id")
            else:
                key = (ev.get("cat"), ev["id"], ev.get("pid"))
                open_async[key] = open_async.get(key, 0) + (
                    1 if ph == "b" else -1)
                if open_async[key] < 0:
                    errs.append(f"{where}: async end without begin "
                                f"for id {ev['id']}")
        elif ph == "i":
            if ev.get("s") not in (None, "t", "p", "g"):
                errs.append(f"{where}: instant scope must be t/p/g")
        elif ph not in ("B", "E", "C"):
            errs.append(f"{where}: unsupported phase {ph!r}")
    for key, depth in open_async.items():
        if depth != 0:
            errs.append(f"async span id {key[1]} left open")
    fr = (doc.get("otherData") or {}).get("flight_recorder") \
        if isinstance(doc.get("otherData"), dict) else None
    if fr is not None:
        if not isinstance(fr, dict):
            errs.append("otherData.flight_recorder must be an object")
        else:
            for key in ("capacity", "events", "dropped"):
                if not isinstance(fr.get(key), int) or fr[key] < 0:
                    errs.append(f"flight_recorder.{key} must be a "
                                f"non-negative integer")
            if not isinstance(fr.get("anomalies"), list):
                errs.append("flight_recorder.anomalies must be a list")
            else:
                for n, a in enumerate(fr["anomalies"]):
                    if not (isinstance(a, dict)
                            and isinstance(a.get("t"), (int, float))
                            and isinstance(a.get("reason"), str)):
                        errs.append(f"flight_recorder.anomalies[{n}]: "
                                    f"needs numeric t and string reason")
    return errs


def validate_metrics_dump(doc: Any) -> List[str]:
    """Errors that would make `doc` an invalid metrics dump (empty list
    = valid). Accepts every schema generation in METRICS_SCHEMAS — v1
    documents (no sketch/SLO sections) stay valid under the v2
    validator; the v2-only sections are validated when present."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document must be an object"]
    if doc.get("schema") not in METRICS_SCHEMAS:
        errs.append(f"schema must be one of {METRICS_SCHEMAS!r}, "
                    f"got {doc.get('schema')!r}")
    for section in ("counters", "gauges", "histograms", "series"):
        if not isinstance(doc.get(section), list):
            errs.append(f"{section} must be a list")
    for kind in ("counters", "gauges"):
        for n, row in enumerate(doc.get(kind) or []):
            if not (isinstance(row, dict) and isinstance(row.get("name"),
                                                         str)
                    and isinstance(row.get("labels"), dict)
                    and isinstance(row.get("value"), (int, float))):
                errs.append(f"{kind}[{n}]: needs name/labels/value")
    for n, row in enumerate(doc.get("histograms") or []):
        if not (isinstance(row, dict) and isinstance(row.get("name"), str)
                and isinstance(row.get("labels"), dict)):
            errs.append(f"histograms[{n}]: needs name/labels")
            continue
        bounds, counts = row.get("bounds"), row.get("counts")
        if not (isinstance(bounds, list) and isinstance(counts, list)
                and len(counts) == len(bounds) + 1):
            errs.append(f"histograms[{n}]: counts must have "
                        f"len(bounds) + 1 buckets")
        if not isinstance(row.get("count"), int):
            errs.append(f"histograms[{n}]: needs an integer count")
    for n, row in enumerate(doc.get("series") or []):
        if not (isinstance(row, dict)
                and isinstance(row.get("t"), (int, float))):
            errs.append(f"series[{n}]: needs a numeric t")
    # v2 optional sections
    if "sketches" in doc:
        if not isinstance(doc["sketches"], list):
            errs.append("sketches must be a list")
        else:
            for n, row in enumerate(doc["sketches"]):
                where = f"sketches[{n}]"
                if not (isinstance(row, dict)
                        and isinstance(row.get("name"), str)
                        and isinstance(row.get("labels"), dict)):
                    errs.append(f"{where}: needs name/labels")
                    continue
                if not (isinstance(row.get("rel_err"), (int, float))
                        and 0 < row["rel_err"] < 1):
                    errs.append(f"{where}: rel_err must be in (0, 1)")
                if not (isinstance(row.get("count"), int)
                        and row["count"] >= 0):
                    errs.append(f"{where}: needs a non-negative count")
                if not isinstance(row.get("sum"), (int, float)):
                    errs.append(f"{where}: needs a numeric sum")
                buckets = row.get("buckets")
                if not isinstance(buckets, list):
                    errs.append(f"{where}: buckets must be a list")
                else:
                    for b in buckets:
                        if not (isinstance(b, list) and len(b) == 2
                                and all(isinstance(x, int) and x >= 0
                                        for x in b)):
                            errs.append(f"{where}: buckets must be "
                                        f"[index, count] integer pairs")
                            break
                    if isinstance(row.get("count"), int) \
                            and sum(b[1] for b in buckets
                                    if isinstance(b, list) and len(b) == 2
                                    and isinstance(b[1], int)) \
                            != row["count"]:
                        errs.append(f"{where}: bucket counts must sum "
                                    f"to count")
    if "slo" in doc and not isinstance(doc["slo"], dict):
        errs.append("slo must be an object")
    return errs
