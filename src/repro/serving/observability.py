"""Serving observability: metrics registry, request-lifecycle tracing,
and exporters (Chrome/Perfetto trace_event JSON + metrics-dump JSON).

The telemetry layer every serving component publishes into (kernels'
dispatch records come via the runner; scheduler, block manager, engine,
replica, and router each have their own instruments). The source
paper's tradeoff — communication vs memory vs computation — is only
navigable with measurements; this module records the signals the
control loops above the engine (SLO autoscaling, adaptive speculation
length) will steer by.

Three pieces:

  * `MetricsRegistry` — labeled counters, gauges, and fixed-bucket
    histograms (e.g. `scheduler_admitted_total{replica=0}`,
    `blocks_cached_gauge`, `verify_accept_len_hist{slot=3}`). Layers
    resolve their instruments ONCE at construction and call
    `inc`/`set`/`observe` on the hot path; the registry also holds the
    periodic `SchedulerStats`-derived time series (`series`) that an
    autoscaler would consume.
  * `Observability` — the recorder handle threaded through the stack.
    Collects trace spans on the SHARED engine/cluster clock: per-slot
    request-lifecycle spans (queued -> routed -> admitted -> prefill ->
    decode -> done), per-dispatch step records (kind, batch, bucket,
    emitted tokens, prefix-cache hits, accept lengths, and a
    `first_dispatch` flag so jit-compile stalls are attributable
    separately from steady-state steps), and async queue spans.
    `scoped(replica)` returns a view sharing all storage but stamping a
    replica label/track id — how a cluster's replicas publish into one
    recorder.
  * exporters — `to_perfetto()` renders the trace as Chrome
    `trace_event` JSON (one process per replica, one thread track per
    slot plus a `dispatch` track; open in https://ui.perfetto.dev),
    `metrics_dump()` renders the registry as a schema-versioned JSON
    document, and `validate_trace_events` / `validate_metrics_dump`
    check both formats (the CI gate).

The default recorder is `NULL_OBS`: every method is a no-op and
`enabled` is False, so layers guard their bookkeeping behind one
attribute check and the off path costs nothing. Recording never
touches device dispatch — with observability on, engine outputs stay
bit-identical to the recorder-off run (gated in serving_bench and
tests/test_observability.py).
"""
from __future__ import annotations

import copy
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# trace_event thread id of the per-replica dispatch track (slot tracks
# use tid == slot index; any real slot count stays far below this)
DISPATCH_TID = 1000

METRICS_SCHEMA = "repro.serving.metrics/v1"
TRACE_SCHEMA = "repro.serving.trace_event/v1"


# ----------------------------------------------------------------------------
# instruments
# ----------------------------------------------------------------------------

class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written value (occupancy, rates)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram: counts[i] counts observations <=
    bounds[i]; counts[-1] is the overflow bucket (> bounds[-1])."""

    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(float(b) for b in bounds)
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing and non-empty: {bounds!r}")
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0


class _NullInstrument:
    """No-op counter/gauge/histogram — what NULL_OBS hands out so hot
    paths can hold one instrument reference unconditionally."""

    __slots__ = ()
    value = 0
    counts: List[int] = []
    total = 0.0
    count = 0
    mean = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(labels.items()))


class MetricsRegistry:
    """Labeled instruments + the stats time series. Instruments are
    keyed (name, sorted labels); resolving the same key returns the
    same object, so layers can cache references at construction and
    `reset()` (per run) zeroes values IN PLACE without invalidating
    them."""

    def __init__(self):
        self._counters: Dict[tuple, Counter] = {}
        self._gauges: Dict[tuple, Gauge] = {}
        self._histograms: Dict[tuple, Histogram] = {}
        self.series: List[Dict[str, Any]] = []

    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        if key not in self._counters:
            self._counters[key] = Counter()
        return self._counters[key]

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        if key not in self._gauges:
            self._gauges[key] = Gauge()
        return self._gauges[key]

    def histogram(self, name: str, bounds: Sequence[float],
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        if key not in self._histograms:
            self._histograms[key] = Histogram(bounds)
        return self._histograms[key]

    def total(self, name: str) -> int:
        """Sum of a counter across every label set (e.g. all replicas)."""
        return sum(c.value for (n, _), c in self._counters.items()
                   if n == name)

    def gauges_named(self, name: str) -> Dict[tuple, float]:
        return {k[1]: g.value for k, g in self._gauges.items()
                if k[0] == name}

    def histograms_named(self, name: str) -> Dict[tuple, Histogram]:
        return {k[1]: h for k, h in self._histograms.items()
                if k[0] == name}

    def reset(self) -> None:
        """Zero every instrument in place and drop the series (per-run
        telemetry); cached instrument references stay valid."""
        for group in (self._counters, self._gauges, self._histograms):
            for inst in group.values():
                inst.reset()
        self.series.clear()

    def to_dict(self) -> Dict[str, Any]:
        def rows(group, extra):
            out = []
            for (name, labels), inst in sorted(group.items()):
                row = {"name": name, "labels": dict(labels)}
                row.update(extra(inst))
                out.append(row)
            return out

        return {
            "counters": rows(self._counters,
                             lambda c: {"value": c.value}),
            "gauges": rows(self._gauges, lambda g: {"value": g.value}),
            "histograms": rows(self._histograms, lambda h: {
                "bounds": list(h.bounds), "counts": list(h.counts),
                "sum": h.total, "count": h.count}),
            "series": list(self.series),
        }


# ----------------------------------------------------------------------------
# the recorder handle
# ----------------------------------------------------------------------------

class Observability:
    """Recorder threaded through every serving layer. One instance (or
    a `scoped(replica)` view of it) is shared by a whole engine stack;
    a cluster shares one root across all replicas so every span sits on
    one clock and every instrument carries its replica label.

    sample_interval   minimum seconds between SchedulerStats time-series
                      samples (0 = record every engine step).
    """

    enabled = True

    def __init__(self, *, sample_interval: float = 0.05):
        self.registry = MetricsRegistry()
        self.sample_interval = float(sample_interval)
        self.replica = 0
        # trace storage (shared across scoped views)
        self.spans: List[Dict[str, Any]] = []     # complete spans
        self.instants: List[Dict[str, Any]] = []  # point events
        self.asyncs: List[Dict[str, Any]] = []    # queue-phase spans
        # mutable cells shared by every scoped view
        self._last_sample = [None]                # [Optional[float]]
        self._last_step: List[Optional[Dict[str, Any]]] = [None]

    # -- scoping ---------------------------------------------------------

    def scoped(self, replica: int) -> "Observability":
        """A view for one replica: shares the registry and all trace
        storage, stamps `replica` on tracks and instrument labels."""
        view = copy.copy(self)
        view.replica = replica
        return view

    def _labels(self, labels: Dict[str, Any]) -> Dict[str, Any]:
        if self.replica:
            labels.setdefault("replica", self.replica)
        return labels

    # -- instruments (replica label folded in) ---------------------------

    def counter(self, name: str, **labels) -> Counter:
        return self.registry.counter(name, **self._labels(labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self.registry.gauge(name, **self._labels(labels))

    def histogram(self, name: str, bounds: Sequence[float],
                  **labels) -> Histogram:
        return self.registry.histogram(name, bounds,
                                       **self._labels(labels))

    # -- trace spans -----------------------------------------------------

    def span(self, tid: int, name: str, cat: str, t0: float, t1: float,
             **args) -> Dict[str, Any]:
        rec = {"pid": self.replica, "tid": tid, "name": name, "cat": cat,
               "t0": t0, "t1": t1, "args": args}
        self.spans.append(rec)
        return rec

    def instant(self, tid: int, name: str, cat: str, t: float,
                **args) -> None:
        self.instants.append({"pid": self.replica, "tid": tid,
                              "name": name, "cat": cat, "t": t,
                              "args": args})

    def async_span(self, name: str, cat: str, aid: int, t0: float,
                   t1: float, **args) -> None:
        """A span that may overlap others (queue residency): rendered as
        Perfetto async b/e pairs keyed by `aid`."""
        self.asyncs.append({"pid": self.replica, "name": name, "cat": cat,
                            "id": aid, "t0": t0, "t1": t1, "args": args})

    # -- dispatch step records -------------------------------------------

    def step(self, kind: str, t0: float, t1: float,
             **args) -> Dict[str, Any]:
        """One device dispatch (prefill / decode / verify) as a span on
        this replica's dispatch track. The record is kept open for
        `annotate_step` — the scheduler adds what the runner cannot know
        (emitted token counts, accept lengths)."""
        rec = self.span(DISPATCH_TID, kind, "dispatch", t0, t1, **args)
        self._last_step[0] = rec
        return rec

    def annotate_step(self, **args) -> None:
        rec = self._last_step[0]
        if rec is not None:
            rec["args"].update(args)

    # -- SchedulerStats time series --------------------------------------

    def sample_stats(self, t: float, stats) -> None:
        """Record occupancy gauges from a SchedulerStats snapshot and,
        subject to `sample_interval` throttling, append a time-series
        sample — the feed an SLO autoscaler consumes."""
        self.gauge("queue_depth_gauge").set(stats.queue_depth)
        self.gauge("active_slots_gauge").set(stats.active_slots)
        self.gauge("blocks_free_gauge").set(stats.free_blocks)
        self.gauge("blocks_cached_gauge").set(stats.cached_blocks)
        self.gauge("blocks_reserved_gauge").set(stats.reserved_blocks)
        spilled = getattr(stats, "spilled_blocks", 0)
        self.gauge("blocks_spilled_gauge").set(spilled)
        preempted = getattr(stats, "preempted", 0)
        self.gauge("preempted_gauge").set(preempted)
        last = self._last_sample[0]
        if last is not None and t - last < self.sample_interval:
            return
        self._last_sample[0] = t
        self.registry.series.append({
            "t": t, "replica": self.replica,
            "queue_depth": stats.queue_depth,
            "active_slots": stats.active_slots,
            "free_slots": stats.free_slots,
            "free_blocks": stats.free_blocks,
            "cached_blocks": stats.cached_blocks,
            "reserved_blocks": stats.reserved_blocks,
            "spilled_blocks": spilled,
            "preempted": preempted,
        })

    # -- lifecycle -------------------------------------------------------

    def begin_run(self) -> None:
        """Per-run reset (mirrors the engine's telemetry semantics):
        drop trace data and zero instruments, keeping instrument
        references valid. Shared storage resets once even when every
        replica's begin_run calls it."""
        self.registry.reset()
        self.spans.clear()
        self.instants.clear()
        self.asyncs.clear()
        self._last_sample[0] = None
        self._last_step[0] = None


class _NullObservability(Observability):
    """The zero-cost default: `enabled` is False (layers skip their
    bookkeeping) and every method is a no-op, so an unguarded call
    costs one dynamic dispatch and records nothing."""

    enabled = False

    def __init__(self):  # no storage at all
        pass

    def scoped(self, replica: int) -> "Observability":
        return self

    def counter(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, bounds: Sequence[float], **labels):
        return _NULL_INSTRUMENT

    def span(self, *a, **k):
        return {}

    def instant(self, *a, **k):
        pass

    def async_span(self, *a, **k):
        pass

    def step(self, *a, **k):
        return {}

    def annotate_step(self, **k):
        pass

    def sample_stats(self, *a, **k):
        pass

    def begin_run(self) -> None:
        pass


NULL_OBS = _NullObservability()


# ----------------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------------

def _us(t: float) -> float:
    return round(t * 1e6, 3)


def to_perfetto(obs: Observability) -> Dict[str, Any]:
    """Render the recorded trace as a Chrome/Perfetto `trace_event`
    document: one process per replica (pid), one thread per slot track
    plus the dispatch track (tid), complete ("X") spans for slot
    residency / lifecycle phases / dispatches, async ("b"/"e") spans
    for queue residency, and metadata naming every track. Timestamps
    are microseconds on the shared run clock."""
    events: List[Dict[str, Any]] = []
    tracks = set()
    for s in obs.spans:
        tracks.add((s["pid"], s["tid"]))
        events.append({"name": s["name"], "cat": s["cat"], "ph": "X",
                       "ts": _us(s["t0"]),
                       "dur": max(_us(s["t1"]) - _us(s["t0"]), 0.0),
                       "pid": s["pid"], "tid": s["tid"],
                       "args": s["args"]})
    for i in obs.instants:
        tracks.add((i["pid"], i["tid"]))
        events.append({"name": i["name"], "cat": i["cat"], "ph": "i",
                       "ts": _us(i["t"]), "s": "t", "pid": i["pid"],
                       "tid": i["tid"], "args": i["args"]})
    for a in obs.asyncs:
        base = {"name": a["name"], "cat": a["cat"],
                "id": str(a["id"]), "pid": a["pid"], "tid": 0}
        events.append({**base, "ph": "b", "ts": _us(a["t0"]),
                       "args": a["args"]})
        events.append({**base, "ph": "e", "ts": _us(a["t1"])})
    for pid in sorted({p for p, _ in tracks} | {a["pid"]
                                                for a in obs.asyncs}):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"replica {pid}"}})
    for pid, tid in sorted(tracks):
        name = "dispatch" if tid == DISPATCH_TID else f"slot {tid}"
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA}}


def metrics_dump(obs: Observability) -> Dict[str, Any]:
    """The registry (plus time series) as a schema-versioned document."""
    doc = {"schema": METRICS_SCHEMA}
    doc.update(obs.registry.to_dict())
    return doc


def export_trace(obs: Observability, path: str) -> Dict[str, Any]:
    doc = to_perfetto(obs)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def export_metrics(obs: Observability, path: str) -> Dict[str, Any]:
    doc = metrics_dump(obs)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


# ----------------------------------------------------------------------------
# schema validation (the CI gate)
# ----------------------------------------------------------------------------

def validate_trace_events(doc: Any) -> List[str]:
    """Errors that would make `doc` invalid Chrome trace_event JSON
    (empty list = loads in Perfetto). Checks the envelope, per-phase
    required fields, and numeric/orderable timestamps."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a traceEvents list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    open_async: Dict[tuple, int] = {}
    for n, ev in enumerate(events):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errs.append(f"{where}: missing ph")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errs.append(f"{where}: missing integer {key}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: ph={ph} needs a non-negative ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event needs dur >= 0")
        elif ph in ("b", "e"):
            if not isinstance(ev.get("id"), str):
                errs.append(f"{where}: async event needs a string id")
            else:
                key = (ev.get("cat"), ev["id"], ev.get("pid"))
                open_async[key] = open_async.get(key, 0) + (
                    1 if ph == "b" else -1)
                if open_async[key] < 0:
                    errs.append(f"{where}: async end without begin "
                                f"for id {ev['id']}")
        elif ph == "i":
            if ev.get("s") not in (None, "t", "p", "g"):
                errs.append(f"{where}: instant scope must be t/p/g")
        elif ph not in ("B", "E", "C"):
            errs.append(f"{where}: unsupported phase {ph!r}")
    for key, depth in open_async.items():
        if depth != 0:
            errs.append(f"async span id {key[1]} left open")
    return errs


def validate_metrics_dump(doc: Any) -> List[str]:
    """Errors that would make `doc` an invalid metrics dump (empty list
    = valid against METRICS_SCHEMA)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document must be an object"]
    if doc.get("schema") != METRICS_SCHEMA:
        errs.append(f"schema must be {METRICS_SCHEMA!r}, "
                    f"got {doc.get('schema')!r}")
    for section in ("counters", "gauges", "histograms", "series"):
        if not isinstance(doc.get(section), list):
            errs.append(f"{section} must be a list")
    for kind in ("counters", "gauges"):
        for n, row in enumerate(doc.get(kind) or []):
            if not (isinstance(row, dict) and isinstance(row.get("name"),
                                                         str)
                    and isinstance(row.get("labels"), dict)
                    and isinstance(row.get("value"), (int, float))):
                errs.append(f"{kind}[{n}]: needs name/labels/value")
    for n, row in enumerate(doc.get("histograms") or []):
        if not (isinstance(row, dict) and isinstance(row.get("name"), str)
                and isinstance(row.get("labels"), dict)):
            errs.append(f"histograms[{n}]: needs name/labels")
            continue
        bounds, counts = row.get("bounds"), row.get("counts")
        if not (isinstance(bounds, list) and isinstance(counts, list)
                and len(counts) == len(bounds) + 1):
            errs.append(f"histograms[{n}]: counts must have "
                        f"len(bounds) + 1 buckets")
        if not isinstance(row.get("count"), int):
            errs.append(f"histograms[{n}]: needs an integer count")
    for n, row in enumerate(doc.get("series") or []):
        if not (isinstance(row, dict)
                and isinstance(row.get("t"), (int, float))):
            errs.append(f"series[{n}]: needs a numeric t")
    return errs
