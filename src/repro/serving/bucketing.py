"""Shared shape-bucketing helpers for the serving engine.

Every jitted dispatch in the runner (batched prefill, multi-token
verify) pads its dynamic extent to a small fixed grid of shapes so the
number of compilations is bounded by the grid, not by the workload.
The grid logic used to live inline in `serving/runner.py` and was
re-derived ad hoc by the bench's shape assertions; it is shared here so
prefill, verify, and the benchmarks agree on one definition.

All helpers deal in plain ints — no device state.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def pow2_buckets(max_value: int, start: int = 1) -> List[int]:
    """Powers of two from `start` up to (and covering) `max_value`.

    The last bucket is next_pow2(max_value), so any extent <= max_value
    fits some bucket. start is clamped to a power of two."""
    buckets, b = [], next_pow2(max(start, 1))
    while b < max_value:
        buckets.append(b)
        b *= 2
    buckets.append(next_pow2(max_value))
    return buckets


def width_buckets(max_batch: int) -> List[int]:
    """Batch-width grid: powers of two below `max_batch`, then
    `max_batch` itself (which need not be a power of two)."""
    out, w = [], 1
    while w < max_batch:
        out.append(w)
        w *= 2
    out.append(max_batch)
    return out


def chain_buckets(speculate: int) -> List[int]:
    """Verify chain-length grid for speculative decoding: powers of two
    from 2 up, topping out at EXACTLY speculate + 1 (a full K-token
    draft — the high-acceptance case — must never pad past its own
    maximum). Chains of length 1 go through plain decode instead."""
    if speculate <= 0:
        return []
    return [b for b in width_buckets(speculate + 1) if b >= 2]


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket covering n (the last bucket if none does —
    callers bound n so this is the exact-fit fallback, not overflow)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def normalize_buckets(buckets: Optional[Sequence[int]], max_value: int,
                      start: int = 1) -> List[int]:
    """Sorted unique user-provided buckets, extended so the grid covers
    `max_value`; None/empty yields the default power-of-two grid."""
    if not buckets:
        return pow2_buckets(max_value, start)
    out = sorted(set(int(b) for b in buckets))
    if out[-1] < max_value:
        out.append(next_pow2(max_value))
    return out
