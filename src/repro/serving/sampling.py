"""Per-request sampling: `SamplingParams` and the batched samplers.

The serving analog of the paper's minibatch-composition independence:
every request carries its own decoding configuration (`SamplingParams`)
and its realization must not depend on which other requests share its
batch. Two design rules make that hold:

  * configs are DATA, not code — temperature / top-k / top-p / seed
    ride through the jitted dispatches as (B,) arrays, so one compiled
    sampler serves every mix of configs (compile count stays bounded by
    the runner's shape buckets, not by distinct configs), and

  * randomness is position-keyed — the token emitted after consuming
    sequence position p draws from fold_in(PRNGKey(seed), p) (plus a
    draw-kind tag), never from engine-global sampler state, so a
    request's stream is a pure function of (its seed, its positions):
    bit-identical whether it runs alone or batched with anything else.

`verify_tokens` is the sampling half of speculative decoding
(Leviathan et al., 2023 accept/reject, specialized to deterministic
draft proposers such as n-gram lookup): draft token d at position p is
accepted with probability q(d) — the target (warped) distribution's
mass on it — and on rejection the correction token is resampled from
q with d masked out, which preserves the target marginal exactly:

    P(emit x) = q(d)·1[x=d] + (1-q(d)) · q(x)·1[x≠d]/(1-q(d)) = q(x).

Greedy lanes (temperature == 0) bypass all of this with a plain argmax
compare, so greedy output under speculation stays bit-identical to
`generate()` — the existing gate. All helpers are pure jnp and safe to
close over in jitted runner dispatches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# draw-kind tags folded into the position key so the accept/reject
# uniform and the (re)sampling categorical at the same position are
# independent draws (reusing one key would correlate the rejection
# event with the correction sample and skew the residual distribution)
TAG_SAMPLE = 0
TAG_ACCEPT = 1


def _normalize_stop(stop) -> Tuple[Tuple[int, ...], ...]:
    if stop is None:
        return ()
    if isinstance(stop, (int,)):
        return ((int(stop),),)
    out = []
    for s in stop:
        if isinstance(s, int):
            out.append((int(s),))
        else:
            seq = tuple(int(t) for t in s)
            if not seq:
                raise ValueError("empty stop sequence")
            out.append(seq)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding configuration.

    temperature     0 = greedy (argmax); > 0 samples from the softmax
    top_k           keep only the k highest logits (0 = disabled)
    top_p           nucleus sampling: keep the smallest set of tokens
                    with cumulative probability >= top_p (1.0 = off)
    seed            per-request PRNG stream; the realization is a pure
                    function of (seed, position) — batch-independent
    max_new_tokens  generation cap (the first token comes from prefill)
    stop            stop token sequences: generation ends when the
                    OUTPUT ends with any of them (the sequence itself
                    is kept, like an eos token; matching never spans
                    into the prompt). An int or a flat int sequence is
                    treated as a single one-token / one-sequence stop.
    logprobs        0 (off) or k >= 1: record the chosen token's
                    log-probability in Completion.logprobs AND the top-k
                    alternative tokens' (ids, logprobs) per emitted
                    position in Completion.top_ids / top_logprobs — all
                    under the RAW model distribution (pre temperature /
                    top-k / top-p), through the decode AND the
                    speculative verify path. True is accepted as 1
                    (back-compat). k is capped by the runner's
                    max_logprobs (the static top-k width it compiles).
    deadline_ms     optional soft TTFT deadline, milliseconds after the
                    request's arrival. Decoding behavior is UNCHANGED;
                    the deadline only matters to a scheduler running
                    with SLO shedding enabled (slo_shed=True), which
                    may shed a queued request it estimates cannot reach
                    its first token in time (finish_reason "shed") and
                    orders admission by deadline slack. With shedding
                    off (the default) it is purely informational.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    stop: Tuple[Tuple[int, ...], ...] = ()
    logprobs: int = 0
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "stop", _normalize_stop(self.stop))
        object.__setattr__(self, "logprobs", int(self.logprobs))
        if self.logprobs < 0:
            raise ValueError(f"logprobs must be >= 0, got {self.logprobs}")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the first "
                             "token is sampled from the prefill logits)")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, "
                             f"got {self.deadline_ms}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def with_seed(self, seed: int) -> "SamplingParams":
        return dataclasses.replace(self, seed=int(seed))


GREEDY = SamplingParams()


def seed32(seed: int) -> int:
    """Fold an arbitrary Python int seed into the int32 range the
    (num_slots,) device seed arrays carry (reinterpreted bits, so
    distinct 32-bit seeds stay distinct)."""
    return int(np.uint32(seed & 0xFFFFFFFF).view(np.int32))


def resolve(sampling: Optional[SamplingParams],
            default: Optional[SamplingParams],
            max_new_tokens: Optional[int] = None,
            eos_id: Optional[int] = None,
            rid: int = 0) -> SamplingParams:
    """Merge a request's SamplingParams with the engine default and the
    legacy per-request fields (max_new_tokens / eos_id). Explicit
    request sampling wins over the engine default; legacy max_new_tokens
    wins over the sampling's cap (old call sites keep their meaning);
    eos_id becomes one more single-token stop sequence.

    A request that carries NO sampling of its own and falls back to a
    sampled engine default gets a per-request stream (default.seed +
    rid) — otherwise every defaulted request would share one seed and
    identical prompts would sample identical outputs (the old engine-
    global-key behavior gave them distinct draws; best-of-n over a
    shared prompt must not collapse to n copies). An EXPLICIT seed is
    never perturbed: reproducing a specific stream stays possible."""
    sp = sampling if sampling is not None else (default or GREEDY)
    changes = {}
    if sampling is None and not sp.greedy:
        changes["seed"] = sp.seed + int(rid)
    if max_new_tokens is not None:
        changes["max_new_tokens"] = int(max_new_tokens)
    if eos_id is not None:
        eos_stop = (int(eos_id),)
        if eos_stop not in sp.stop:
            changes["stop"] = sp.stop + (eos_stop,)
    return dataclasses.replace(sp, **changes) if changes else sp


# ----------------------------------------------------------------------------
# jnp samplers (batched, config-as-data)
# ----------------------------------------------------------------------------

def position_key(seed, pos, tag):
    """The key for one draw: fold the absolute sequence position and the
    draw-kind tag into the request's stream. Pure in (seed, pos, tag)."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), pos), tag)


def _keys(seeds, positions, tag):
    """Batched position keys; seeds/positions may be (B,) or (B, T)."""
    flat = jax.vmap(lambda s, p: position_key(s, p, tag))(
        seeds.reshape(-1), positions.reshape(-1))
    return flat.reshape(positions.shape + flat.shape[1:])


def warp_logits(logits, temperature, top_k, top_p):
    """Apply temperature / top-k / top-p to logits (..., V); the scalar
    params broadcast over the leading dims ((...,)-shaped arrays).
    softmax(warped) is the target sampling distribution. Masked tokens
    go to -inf. Greedy rows (temperature 0) are scaled by 1 — callers
    select argmax for them, the warp result is unused."""
    V = logits.shape[-1]
    t = jnp.where(temperature > 0, temperature, 1.0)[..., None]
    x = logits / t
    xs = -jnp.sort(-x, axis=-1)                       # descending
    k = jnp.clip(jnp.where(top_k <= 0, V, top_k), 1, V)
    kth = jnp.take_along_axis(xs, (k - 1)[..., None], axis=-1)
    # top-p: softmax is order-preserving, so the nucleus is a prefix of
    # the SAME descending sort — keep tokens whose cumulative mass
    # BEFORE them is < p (so the first token is always kept), then
    # translate back to a logit threshold
    ps = jax.nn.softmax(xs, axis=-1)
    keep = (jnp.cumsum(ps, axis=-1) - ps) < top_p[..., None]
    pth = jnp.min(jnp.where(keep, xs, jnp.inf), axis=-1, keepdims=True)
    pth = jnp.where((top_p >= 1.0)[..., None], -jnp.inf, pth)
    thr = jnp.maximum(kth, pth)
    return jnp.where(x >= thr, x, -jnp.inf)


def _categorical(keys, logits):
    """Per-row-keyed categorical over the last axis; keys/logits share
    leading dims ((B,) or (B, T))."""
    flat_keys = keys.reshape((-1,) + keys.shape[len(logits.shape) - 1:])
    flat_logits = logits.reshape((-1, logits.shape[-1]))
    tok = jax.vmap(jax.random.categorical)(flat_keys, flat_logits)
    return tok.reshape(logits.shape[:-1])


def _chosen_logprob(logits, tokens):
    """Log-probability of `tokens` under the RAW model distribution."""
    lp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(lp, tokens[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)


def greedy_tokens(logits):
    """Argmax fast path: (tokens, chosen logprobs) for (..., V) logits."""
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return tok, _chosen_logprob(logits, tok)


def top_alternatives(logits, k: int):
    """Top-k alternative tokens per position under the RAW model
    distribution: ((..., k) int32 ids, (..., k) float32 logprobs),
    descending. `k` is static (a compile-time width); requests asking
    for fewer slice the leading columns host-side."""
    lp = jax.nn.log_softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(lp, k)
    return ids.astype(jnp.int32), vals.astype(jnp.float32)


def _shift_draft(chain):
    """Align the chain with its logits: the draft checked at logits
    index t is chain token t+1 (the pad column is never a real draft)."""
    return jnp.concatenate(
        [chain[:, 1:], jnp.zeros_like(chain[:, :1])], axis=1)


def _lead_accepts(acc, counts):
    """Number of leading accepted drafts per lane: only the first
    counts-1 chain positions carry real drafts, and the run stops at
    the first rejection."""
    T = acc.shape[1]
    valid = jnp.arange(T, dtype=jnp.int32)[None, :] < counts[:, None] - 1
    return jnp.cumprod((acc & valid).astype(jnp.int32),
                       axis=1).sum(axis=1).astype(jnp.int32)


def greedy_verify_tokens(logits, chain, counts):
    """The argmax accept rule for a whole verify dispatch (the fast
    path when every live slot is greedy — ONE definition shared with
    the greedy lanes inside `verify_tokens`, so the two traces cannot
    drift): emit the model argmax at every position and accept the
    longest draft prefix agreeing with it. Returns (emit (B, T) int32,
    accept (B,) int32, chosen logprobs (B, T) float32)."""
    model_tok, lp = greedy_tokens(logits)
    accept = _lead_accepts(model_tok == _shift_draft(chain), counts)
    return model_tok, accept, lp


def sample_tokens(logits, positions, temperature, top_k, top_p, seeds):
    """One batched next-token draw with per-lane configs.

    logits (B, V); positions (B,) absolute position of the token each
    lane just consumed (the key for this draw); temperature/top_p (B,)
    float, top_k/seeds (B,) int. Greedy lanes take the argmax; sampled
    lanes draw categorical(fold_in(PRNGKey(seed), pos)) over the warped
    logits. Returns ((B,) int32 tokens, (B,) float32 chosen logprobs)."""
    warped = warp_logits(logits, temperature, top_k, top_p)
    sampled = _categorical(_keys(seeds, positions, TAG_SAMPLE), warped)
    tok = jnp.where(temperature > 0, sampled,
                    jnp.argmax(logits, axis=-1)).astype(jnp.int32)
    return tok, _chosen_logprob(logits, tok)


def verify_tokens(logits, chain, counts, positions, temperature, top_k,
                  top_p, seeds):
    """Accept/reject + emission for one verify dispatch (the sampling
    half of speculative decoding, deterministic-draft specialization).

    logits (B, T, V): next-token logits after consuming chain token t;
    chain (B, T): [pending, d_1 .. d_k] right-padded; counts (B,) true
    chain lengths (0 = lane sits out); positions (B,) absolute position
    of each chain's first token; temperature/top_k/top_p/seeds (B,).

    Per lane, draft d_{t+1} (checked against logits index t) is:
      greedy lane   accepted iff argmax(logits[t]) == d_{t+1}
      sampled lane  accepted with probability q_t(d_{t+1}) where q_t =
                    softmax(warp(logits[t])) — the Leviathan rule with a
                    deterministic (probability-one) proposal
    `accept` is the number of leading accepted drafts; the emitted run
    is the accepted drafts plus ONE more token at index `accept`:
      greedy         the model argmax (correction == bonus)
      sampled, a<k   resampled from q_a with the rejected draft masked
                     out (the residual distribution)
      sampled, a==k  the bonus token, a plain draw from q_k
    Accept uniforms and (re)samples use different key tags, so the
    marginal of the emitted token at every position is exactly q — the
    distribution-preservation property the tiny-vocab test pins.

    Returns (emit (B, T) int32 — valid at indices 0..accept —,
    accept (B,) int32, chosen logprobs (B, T) float32)."""
    B, T = chain.shape
    tidx = jnp.arange(T, dtype=jnp.int32)[None, :]
    pos = positions[:, None] + tidx                         # (B, T)
    seeds_bt = jnp.broadcast_to(seeds[:, None], (B, T))
    model_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    draft = _shift_draft(chain)
    warped = warp_logits(logits, temperature[:, None], top_k[:, None],
                         top_p[:, None])
    q_draft = jnp.exp(jnp.take_along_axis(
        jax.nn.log_softmax(warped, axis=-1), draft[..., None],
        axis=-1)[..., 0])
    u = jax.vmap(jax.vmap(jax.random.uniform))(
        _keys(seeds_bt, pos, TAG_ACCEPT))
    accept = _lead_accepts(
        jnp.where(temperature[:, None] > 0, u < q_draft,
                  model_tok == draft), counts)
    skeys = _keys(seeds_bt, pos, TAG_SAMPLE)
    residual = jnp.where(
        jax.nn.one_hot(draft, logits.shape[-1], dtype=bool), -jnp.inf,
        warped)
    resample = _categorical(skeys, residual)      # rejection correction
    bonus = _categorical(skeys, warped)           # full-accept bonus
    full = (accept >= jnp.maximum(counts, 1) - 1)[:, None]
    emit_sampled = jnp.where(tidx < accept[:, None], draft,
                             jnp.where(full, bonus, resample))
    emit = jnp.where(temperature[:, None] > 0, emit_sampled,
                     model_tok).astype(jnp.int32)
    return emit, accept.astype(jnp.int32), _chosen_logprob(logits, emit)
