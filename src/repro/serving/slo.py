"""SLO layer: streaming latency sketches, burn rates, and the
burn-rate autoscale signal — the measurement half of closed-loop
serving control.

The source paper's minibatch-prox argument is a time-budget argument:
do the statistically right amount of work per round given the costs
you actually observe. This module gives the serving stack the observed
side of that loop. Three pieces:

  * `QuantileSketch` — a bounded-memory streaming quantile estimator:
    a fixed log-spaced-bucket histogram (the DDSketch bucket layout)
    whose bucket midpoints pin every quantile estimate within a
    declared RELATIVE error bound of the exact order statistic. With
    gamma = (1 + rel_err) / (1 - rel_err), bucket i covers
    (min_value * gamma^(i-1), min_value * gamma^i] and reports the
    midpoint, so |estimate - exact| <= rel_err * exact for any value
    in (min_value, max_value] — tested against numpy's exact
    nearest-rank quantile on adversarial distributions. Memory is
    FIXED at construction (the bucket array), never grows with the
    stream, and two sketches with the same config merge by adding
    counts (exactly — the estimator is a counting histogram).

  * `SLOPolicy` + `SLOTracker` — declared objectives (per-priority-
    class TTFT, a global e2e latency objective, an error budget) and
    the live accounting against them: per-(metric, class) sketches for
    TTFT / TPOT / e2e latency plus time-bucketed good/bad windows that
    yield the multi-window BURN RATE, the SRE alerting quantity:

        burn(now, W) = (bad fraction over the last W seconds)
                       / error_budget

    burn == 1 means the service is spending its error budget exactly
    at the sustainable rate; burn > 1 over the FAST window catches an
    active incident quickly, while the SLOW window filters blips —
    the classic multi-window burn-rate alert, here feeding actuators
    instead of a pager.

  * `SLOSignal` — the burn-rate alternative to the queue-depth
    `AutoscaleController`: same observe(t, queue_depth, active_slots,
    n_replicas) -> 'out' / 'in' / None interface (drop-in for
    `Autoscaler(..., controller=...)`), but the decision input is the
    tracker's TTFT burn rate — scale out on sustained burn > 1 of the
    TTFT objective, scale in on sustained burn well below budget —
    with the same sustain-window + cooldown hysteresis so it cannot
    flap. Queue depth is ignored by design: this signal scales on what
    users experience, not on what the queue looks like.

The scheduler's shed / defer admission decisions (serving/scheduler.py)
read the SAME tracker: the live TTFT estimate (`ttft_quantile(0.5)`)
prices a queued request's expected wait against its deadline. All of
this is measurement-side only — nothing here touches device dispatch,
and with no tracker attached every hook costs one `is not None` check.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.observability import NULL_OBS, Observability

# trace_event track for SLO control-plane instants (shed / defer /
# breach markers). Slot tracks use tid == slot index, the autoscaler
# uses CONTROL_TID = 90 — keep clear of both.
SLO_TID = 91


# ----------------------------------------------------------------------------
# streaming quantile sketch
# ----------------------------------------------------------------------------

class QuantileSketch:
    """Bounded-memory streaming quantiles with a pinned relative-error
    bound (log-spaced buckets, DDSketch layout).

    rel_err     guaranteed bound: for any q and any stream of values in
                (min_value, max_value], |quantile(q) - exact| <=
                rel_err * exact, where `exact` is the nearest-rank
                order statistic (numpy.quantile method='inverted_cdf')
    min_value   absolute floor: values at or below it collapse into
                bucket 0 and report min_value (the bound is absolute,
                not relative, down there — pick it below any latency
                you care to distinguish)
    max_value   ceiling: larger values clamp into the top bucket

    Memory is fixed at construction: ceil(log_gamma(max/min)) + 1
    integer buckets (~1000 for microseconds-to-an-hour at 1%), never
    grows with the stream.
    """

    __slots__ = ("rel_err", "min_value", "max_value", "gamma",
                 "_log_gamma", "counts", "count", "total")

    def __init__(self, rel_err: float = 0.01, *, min_value: float = 1e-5,
                 max_value: float = 3600.0):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        if not 0.0 < min_value < max_value:
            raise ValueError("need 0 < min_value < max_value")
        self.rel_err = float(rel_err)
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._log_gamma = math.log(self.gamma)
        n = int(math.ceil(math.log(max_value / min_value)
                          / self._log_gamma)) + 1
        self.counts = [0] * n
        self.count = 0
        self.total = 0.0

    def _index(self, v: float) -> int:
        if v <= self.min_value:
            return 0
        i = int(math.ceil(math.log(v / self.min_value) / self._log_gamma))
        return min(i, len(self.counts) - 1)

    def _value(self, i: int) -> float:
        if i <= 0:
            return self.min_value
        # midpoint of (min * gamma^(i-1), min * gamma^i]: relative
        # error vs anything in the bucket is (gamma-1)/(gamma+1) ==
        # rel_err — the pinned bound
        return self.min_value * (self.gamma ** (i - 1)) \
            * (1.0 + self.gamma) / 2.0

    def observe(self, v: float) -> None:
        self.counts[self._index(v)] += 1
        self.count += 1
        self.total += v

    def quantile(self, q: float) -> Optional[float]:
        """Nearest-rank quantile estimate (None on an empty sketch):
        the midpoint of the bucket holding the ceil(q*n)-th ordered
        observation — within rel_err of the exact order statistic."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return None
        rank = max(1, int(math.ceil(q * self.count)))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self._value(i)
        return self._value(len(self.counts) - 1)   # unreachable

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Add another sketch's counts into this one (exact — the
        merged sketch equals the sketch of the concatenated streams).
        Configs must match bucket-for-bucket."""
        if (other.rel_err != self.rel_err
                or other.min_value != self.min_value
                or other.max_value != self.max_value):
            raise ValueError("cannot merge sketches with different "
                             "rel_err/min_value/max_value")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        return self

    def reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.count = 0
        self.total = 0.0

    def to_dict(self) -> Dict:
        """Sparse dump row (metrics-dump `sketches` section): only the
        occupied buckets, as [index, count] pairs."""
        return {"rel_err": self.rel_err, "min_value": self.min_value,
                "max_value": self.max_value, "count": self.count,
                "sum": self.total,
                "buckets": [[i, c] for i, c in enumerate(self.counts)
                            if c]}


# ----------------------------------------------------------------------------
# policy + burn-rate windows
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Declared service-level objectives.

    ttft_objective_ms     TTFT target: a request whose first token
                          lands later than this is a BAD event
    class_ttft_ms         per-priority-class TTFT overrides as
                          ((priority, objective_ms), ...) pairs —
                          classes not listed use ttft_objective_ms
    latency_objective_ms  e2e latency target (None = no e2e objective)
    error_budget          allowed BAD fraction: burn rate is the
                          observed bad fraction divided by this
    fast_window_s         burn-rate detection window (incident-fast)
    slow_window_s         burn-rate confirmation window (blip filter);
                          also how long window history is retained
    """
    ttft_objective_ms: float = 200.0
    class_ttft_ms: Tuple[Tuple[int, float], ...] = ()
    latency_objective_ms: Optional[float] = None
    error_budget: float = 0.1
    fast_window_s: float = 0.25
    slow_window_s: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "class_ttft_ms",
                           tuple((int(p), float(o))
                                 for p, o in self.class_ttft_ms))
        if self.ttft_objective_ms <= 0:
            raise ValueError("ttft_objective_ms must be > 0")
        for p, o in self.class_ttft_ms:
            if o <= 0:
                raise ValueError(f"class {p}: objective must be > 0")
        if self.latency_objective_ms is not None \
                and self.latency_objective_ms <= 0:
            raise ValueError("latency_objective_ms must be > 0")
        if not 0.0 < self.error_budget < 1.0:
            raise ValueError("error_budget must be in (0, 1)")
        if not 0.0 < self.fast_window_s < self.slow_window_s:
            raise ValueError("need 0 < fast_window_s < slow_window_s")

    def ttft_objective_s(self, priority: int = 0) -> float:
        for p, o in self.class_ttft_ms:
            if p == priority:
                return o / 1e3
        return self.ttft_objective_ms / 1e3

    def latency_objective_s(self, priority: int = 0) -> Optional[float]:
        if self.latency_objective_ms is None:
            return None
        return self.latency_objective_ms / 1e3


class _BurnWindow:
    """Time-bucketed good/bad event counts for windowed burn rates:
    a deque of [bucket_t0, total, bad] rows at `bucket_s` granularity,
    pruned past `keep_s` — bounded memory for any stream length."""

    __slots__ = ("bucket_s", "keep_s", "_rows", "ever")

    def __init__(self, bucket_s: float, keep_s: float):
        self.bucket_s = float(bucket_s)
        self.keep_s = float(keep_s)
        self._rows: Deque[List[float]] = deque()
        self.ever = 0                 # observations over all time

    def observe(self, t: float, bad: bool) -> None:
        b0 = math.floor(t / self.bucket_s) * self.bucket_s
        if not self._rows or self._rows[-1][0] < b0:
            self._rows.append([b0, 0, 0])
        self._rows[-1][1] += 1
        self._rows[-1][2] += int(bad)
        self.ever += 1
        self._prune(t)

    def _prune(self, now: float) -> None:
        edge = now - self.keep_s - self.bucket_s
        while self._rows and self._rows[0][0] < edge:
            self._rows.popleft()

    def fraction(self, now: float, window_s: float) -> Optional[float]:
        """Bad fraction over [now - window_s, now]; 0.0 for an idle
        window once anything was ever observed (no traffic = no budget
        spent), None before the first observation ever."""
        self._prune(now)
        lo = now - window_s
        total = bad = 0
        for t0, n, b in self._rows:
            if t0 + self.bucket_s > lo:
                total += n
                bad += b
        if total == 0:
            return 0.0 if self.ever else None
        return bad / total

    def reset(self) -> None:
        self._rows.clear()
        self.ever = 0


class SLOTracker:
    """Live SLO accounting: per-(metric, priority-class) quantile
    sketches plus burn-rate windows against an `SLOPolicy`.

    One tracker is shared by every consumer of the same objectives —
    the scheduler feeds it observations (TTFT at first token, TPOT and
    e2e latency at completion) and reads the live TTFT estimate for
    shed/defer admission; `SLOSignal` reads burn rates for scaling; a
    cluster's replicas share one tracker so burn is cluster-wide.

    observe_* return True when the observation breached its objective
    (the caller's hook for breach counters / flight-recorder triggers).
    """

    METRICS = ("ttft", "tpot", "latency")

    def __init__(self, policy: SLOPolicy, *, rel_err: float = 0.01,
                 bucket_s: float = 0.05):
        self.policy = policy
        self.rel_err = float(rel_err)
        self.bucket_s = float(bucket_s)
        self._sketches: Dict[Tuple[str, int], QuantileSketch] = {}
        keep = policy.slow_window_s
        self._windows = {m: _BurnWindow(bucket_s, keep)
                         for m in ("ttft", "latency")}
        self.breaches = {"ttft": 0, "latency": 0}
        self.peak_burn = {"fast": 0.0, "slow": 0.0}

    def _sketch(self, metric: str, priority: int) -> QuantileSketch:
        key = (metric, int(priority))
        sk = self._sketches.get(key)
        if sk is None:
            sk = self._sketches[key] = QuantileSketch(self.rel_err)
        return sk

    # -- feeding ---------------------------------------------------------

    def observe_ttft(self, t: float, value_s: float,
                     priority: int = 0) -> bool:
        self._sketch("ttft", priority).observe(value_s)
        bad = value_s > self.policy.ttft_objective_s(priority)
        self._windows["ttft"].observe(t, bad)
        if bad:
            self.breaches["ttft"] += 1
        return bad

    def observe_latency(self, t: float, value_s: float,
                        priority: int = 0) -> bool:
        self._sketch("latency", priority).observe(value_s)
        obj = self.policy.latency_objective_s(priority)
        bad = obj is not None and value_s > obj
        if obj is not None:
            self._windows["latency"].observe(t, bad)
            if bad:
                self.breaches["latency"] += 1
        return bad

    def observe_tpot(self, t: float, value_s: float,
                     priority: int = 0) -> None:
        self._sketch("tpot", priority).observe(value_s)

    # -- reading ---------------------------------------------------------

    def quantile(self, metric: str, q: float,
                 priority: Optional[int] = None) -> Optional[float]:
        """Quantile estimate in seconds for one class, or across every
        class (priority=None, sketches merged); None with no data."""
        if priority is not None:
            sk = self._sketches.get((metric, int(priority)))
            return sk.quantile(q) if sk is not None else None
        merged: Optional[QuantileSketch] = None
        for (m, _), sk in self._sketches.items():
            if m != metric or sk.count == 0:
                continue
            if merged is None:
                merged = QuantileSketch(self.rel_err)
            merged.merge(sk)
        return merged.quantile(q) if merged is not None else None

    def ttft_quantile(self, q: float,
                      priority: Optional[int] = None) -> Optional[float]:
        return self.quantile("ttft", q, priority)

    def burn_rate(self, now: float, window_s: float,
                  metric: str = "ttft") -> Optional[float]:
        """(bad fraction over the last window_s) / error_budget; 0.0
        for idle windows after any traffic, None before any."""
        frac = self._windows[metric].fraction(now, window_s)
        if frac is None:
            return None
        return frac / self.policy.error_budget

    def tick(self, now: float) -> Tuple[Optional[float], Optional[float]]:
        """The control-loop read: TTFT burn over the policy's fast and
        slow windows, with run peaks recorded (what the bench gates
        on: peak fast burn > 1 during the burst)."""
        fast = self.burn_rate(now, self.policy.fast_window_s)
        slow = self.burn_rate(now, self.policy.slow_window_s)
        if fast is not None:
            self.peak_burn["fast"] = max(self.peak_burn["fast"], fast)
        if slow is not None:
            self.peak_burn["slow"] = max(self.peak_burn["slow"], slow)
        return fast, slow

    # -- lifecycle / export ----------------------------------------------

    def reset(self) -> None:
        for sk in self._sketches.values():
            sk.reset()
        for w in self._windows.values():
            w.reset()
        self.breaches = {"ttft": 0, "latency": 0}
        self.peak_burn = {"fast": 0.0, "slow": 0.0}

    def sketch_rows(self) -> List[Dict]:
        """Metrics-dump `sketches` section: one row per (metric,
        class) sketch, sparse-bucket encoded."""
        rows = []
        for (metric, prio), sk in sorted(self._sketches.items()):
            if sk.count == 0:
                continue
            row = {"name": f"slo_{metric}_sketch",
                   "labels": {"priority": prio}}
            row.update(sk.to_dict())
            rows.append(row)
        return rows

    def snapshot(self) -> Dict:
        """The summary block a bench record embeds: policy, breach
        counts, peak burn, and headline quantile estimates (ms)."""
        def q_ms(metric, q):
            v = self.quantile(metric, q)
            return round(v * 1e3, 3) if v is not None else None

        return {
            "policy": dataclasses.asdict(self.policy),
            "sketch_rel_err": self.rel_err,
            "observed": {m: sum(sk.count
                                for (mm, _), sk in self._sketches.items()
                                if mm == m)
                         for m in self.METRICS},
            "breaches": dict(self.breaches),
            "peak_burn": {k: round(v, 3)
                          for k, v in self.peak_burn.items()},
            "ttft_p50_ms": q_ms("ttft", 0.5),
            "ttft_p99_ms": q_ms("ttft", 0.99),
            "latency_p99_ms": q_ms("latency", 0.99),
        }


# ----------------------------------------------------------------------------
# the burn-rate autoscale signal
# ----------------------------------------------------------------------------

class SLOSignal:
    """Burn-rate-driven scaling decisions: a drop-in alternative to the
    queue-depth `AutoscaleController` (same observe() contract, same
    sustain-window + cooldown hysteresis), selectable per run via
    `Autoscaler(..., controller=SLOSignal(...))`.

    scale out   TTFT burn over the policy's FAST window above
                `scale_out_burn` (default 1.0: spending budget faster
                than sustainable) sustained for `high_window_s`
    scale in    TTFT burn over the SLOW window below `scale_in_burn`
                (default 0.25: well under budget) sustained for
                `low_window_s` — the slow window plus the lower
                threshold is the hysteresis band

    The AutoscalePolicy supplies replica bounds, sustain windows, and
    the cooldown; its queue_high/queue_low bands are ignored — this
    signal scales on observed user latency, not queue shape. Before
    any completion lands, burn is undefined and no decision fires (a
    cold cluster scales on nothing)."""

    kind = "slo-burn-rate"

    def __init__(self, tracker: SLOTracker, policy, *,
                 scale_out_burn: float = 1.0, scale_in_burn: float = 0.25,
                 obs: Observability = NULL_OBS):
        if not 0.0 <= scale_in_burn < scale_out_burn:
            raise ValueError("need 0 <= scale_in_burn < scale_out_burn "
                             "(the hysteresis band)")
        self.tracker = tracker
        self.policy = policy
        self.scale_out_burn = float(scale_out_burn)
        self.scale_in_burn = float(scale_in_burn)
        self._obs = obs or NULL_OBS
        self._g_fast = self._obs.gauge("slo_burn_rate_fast_gauge")
        self._g_slow = self._obs.gauge("slo_burn_rate_slow_gauge")
        self.reset()

    def reset(self) -> None:
        self._above_since: Optional[float] = None
        self._below_since: Optional[float] = None
        self._last_decision = float("-inf")

    def observe(self, t: float, queue_depth: float, active_slots: float,
                n_replicas: int) -> Optional[str]:
        """Same contract as AutoscaleController.observe — queue/slot
        occupancy are accepted (the Autoscaler feeds them) but the
        decision reads only the tracker's burn rates."""
        p = self.policy
        fast, slow = self.tracker.tick(t)
        self._g_fast.set(fast or 0.0)
        self._g_slow.set(slow or 0.0)
        if fast is not None and fast > self.scale_out_burn:
            if self._above_since is None:
                self._above_since = t
        else:
            self._above_since = None
        if slow is not None and slow < self.scale_in_burn:
            if self._below_since is None:
                self._below_since = t
        else:
            self._below_since = None
        cool = (t - self._last_decision) >= p.cooldown_s
        if (self._above_since is not None
                and n_replicas < p.max_replicas and cool
                and t - self._above_since >= p.high_window_s):
            self._last_decision = t
            self._above_since = None
            return "out"
        if (self._below_since is not None
                and n_replicas > p.min_replicas and cool
                and t - self._below_since >= p.low_window_s):
            self._last_decision = t
            self._below_since = None
            return "in"
        return None
