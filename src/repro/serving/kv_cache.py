"""Paged KV cache: fixed-size physical blocks + per-sequence block tables.

The seed decode path allocates (batch, max_len, KV, hd) per layer — memory
scales with the worst case whether or not tokens exist. Here every layer's
cache is a pool of `num_blocks` blocks of `block_size` tokens; a sequence
occupying `n` tokens holds ceil(n / block_size) blocks, found through its
block-table row. Memory scales with LIVE tokens across all slots — the
serving-side analogue of the paper's hold-a-minibatch memory accounting
(cache capacity is a token budget, not a batch x max_len rectangle).

Block 0 is reserved as the null sink: inactive decode slots point their
table rows at it, so the always-full-batch decode step has somewhere
harmless to write. The allocator never hands it out.

Layer-state layout (mirrors models/lm.init_decode_state):
  attention layers   {"k","v"}: (num_blocks, block_size, KV, hd) pools,
                     stacked layers carry a leading n_super axis;
  recurrent layers   slot-indexed dense state, (num_slots, ...) per leaf —
                     O(num_slots), no paging needed.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm

NULL_BLOCK = 0

_ATTN_KINDS = ("attn", "attn_local", "moe")


class BlockAllocator:
    """Free-list allocator over the physical block pool.

    Invariants (tested under random admit/evict churn):
      * a block is owned by at most one sequence at a time,
      * alloc returns None (not a partial grant) when short,
      * freeing unowned blocks / the null block raises.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._used: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n blocks, or None if the pool can't cover the request."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._used.update(blocks)
        return blocks

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the reserved null block")
            if b not in self._used:
                raise ValueError(f"double free / unowned block {b}")
            self._used.remove(b)
            self._free.append(b)


def init_paged_state(cfg: ModelConfig, num_slots: int, num_blocks: int,
                     block_size: int):
    """Paged decode-state pytree (same layer tree as init_decode_state)."""
    dt = cfg.act_dtype

    def layer_state(kind):
        if kind in _ATTN_KINDS:
            shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        return lm._init_block_state(cfg, kind, num_slots, 0, dt)

    state = {"prefix": [layer_state(k) for k in cfg.prefix_pattern]}
    blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        one = layer_state(kind)
        blocks[f"p{pi}"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_super,) + x.shape, x.dtype), one)
    state["blocks"] = blocks
    return state


def paged_bytes(cfg: ModelConfig, num_blocks: int, block_size: int) -> int:
    """Attention-cache bytes of the pool (the memory the paging bounds)."""
    n_attn = (sum(k in _ATTN_KINDS for k in cfg.prefix_pattern)
              + cfg.n_super * sum(k in _ATTN_KINDS
                                  for k in cfg.block_pattern))
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * cfg.act_dtype.itemsize
    return n_attn * num_blocks * block_size * per_tok


def load_prefill(cfg: ModelConfig, state, cache, slot, table_row,
                 block_size: int):
    """Scatter one sequence's prefill cache (lm.prefill, batch=1) into the
    paged slot state.

    `slot` (int32 scalar) and `table_row` ((max_blocks,) int32) are traced,
    so one jitted instance serves every slot; the prompt length is static
    from `cache` leaf shapes. Attention K/V of prompt position p lands in
    physical block table_row[p // block_size], offset p % block_size;
    recurrent final states land at the slot index.
    """
    def attn_positions(n_tok):
        pos = jnp.arange(n_tok)
        return table_row[pos // block_size], pos % block_size

    def load_layer(kind, st, ca, stacked):
        if kind in _ATTN_KINDS:
            # ca k/v: (B=1, P, KV, hd), stacked: (n_super, 1, P, KV, hd)
            n_tok = ca["k"].shape[2] if stacked else ca["k"].shape[1]
            blk, off = attn_positions(n_tok)
            if stacked:
                return {"k": st["k"].at[:, blk, off].set(ca["k"][:, 0]),
                        "v": st["v"].at[:, blk, off].set(ca["v"][:, 0])}
            return {"k": st["k"].at[blk, off].set(ca["k"][0]),
                    "v": st["v"].at[blk, off].set(ca["v"][0])}
        if stacked:
            return jax.tree.map(lambda s, c: s.at[:, slot].set(c[:, 0]),
                                st, ca)
        return jax.tree.map(lambda s, c: s.at[slot].set(c[0]), st, ca)

    new_prefix = [load_layer(kind, st, ca, False)
                  for kind, st, ca in zip(cfg.prefix_pattern,
                                          state["prefix"], cache["prefix"])]
    new_blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        key = f"p{pi}"
        new_blocks[key] = load_layer(kind, state["blocks"][key],
                                     cache["blocks"][key], True)
    return {"prefix": new_prefix, "blocks": new_blocks}
