"""Paged KV cache: fixed-size physical blocks + per-sequence block tables.

The seed decode path allocates (batch, max_len, KV, hd) per layer — memory
scales with the worst case whether or not tokens exist. Here every layer's
cache is a pool of `num_blocks` blocks of `block_size` tokens; a sequence
occupying `n` tokens holds ceil(n / block_size) blocks, found through its
block-table row. Memory scales with LIVE tokens across all slots — the
serving-side analogue of the paper's hold-a-minibatch memory accounting
(cache capacity is a token budget, not a batch x max_len rectangle).

Block 0 is reserved as the null sink: inactive decode slots point their
table rows at it, so the always-full-batch decode step has somewhere
harmless to write. The allocator never hands it out.

Layer-state layout (mirrors models/lm.init_decode_state):
  attention layers   {"k","v"}: (num_blocks, block_size, KV, hd) pools,
                     stacked layers carry a leading n_super axis;
  recurrent layers   slot-indexed dense state, (num_slots, ...) per leaf —
                     O(num_slots), no paging needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
# BlockAllocator grew refcounts + the prefix-cache index and moved to its
# own layer; re-exported here for backward compatibility.
from repro.serving.block_manager import NULL_BLOCK, BlockAllocator  # noqa: F401

# block kinds whose KV lives in the paged pools (canonical set —
# the engine's prefix-cache gate and copy_block both key off it)
ATTN_KINDS = ("attn", "attn_local", "moe")


def init_paged_state(cfg: ModelConfig, num_slots: int, num_blocks: int,
                     block_size: int):
    """Paged decode-state pytree (same layer tree as init_decode_state)."""
    dt = cfg.act_dtype

    def layer_state(kind):
        if kind in ATTN_KINDS:
            shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        return lm._init_block_state(cfg, kind, num_slots, 0, dt)

    state = {"prefix": [layer_state(k) for k in cfg.prefix_pattern]}
    blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        one = layer_state(kind)
        blocks[f"p{pi}"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_super,) + x.shape, x.dtype), one)
    state["blocks"] = blocks
    return state


def paged_bytes(cfg: ModelConfig, num_blocks: int, block_size: int) -> int:
    """Attention-cache bytes of the pool (the memory the paging bounds)."""
    n_attn = (sum(k in ATTN_KINDS for k in cfg.prefix_pattern)
              + cfg.n_super * sum(k in ATTN_KINDS
                                  for k in cfg.block_pattern))
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * cfg.act_dtype.itemsize
    return n_attn * num_blocks * block_size * per_tok


def copy_block(cfg: ModelConfig, state, src, dst):
    """Copy one physical block's K/V in every attention pool (src/dst are
    traced int32 block ids, so one jitted instance serves all copies).
    The copy-on-write primitive: a sequence that must write into a shared
    prompt block gets a private copy first (see serving/scheduler.py).
    Recurrent slot state is untouched — it is per-slot, never shared."""

    def copy_layer(kind, st, stacked):
        if kind not in ATTN_KINDS:
            return st
        if stacked:
            return {"k": st["k"].at[:, dst].set(st["k"][:, src]),
                    "v": st["v"].at[:, dst].set(st["v"][:, src])}
        return {"k": st["k"].at[dst].set(st["k"][src]),
                "v": st["v"].at[dst].set(st["v"][src])}

    new_prefix = [copy_layer(kind, st, False)
                  for kind, st in zip(cfg.prefix_pattern, state["prefix"])]
    new_blocks = {f"p{pi}": copy_layer(kind, state["blocks"][f"p{pi}"], True)
                  for pi, kind in enumerate(cfg.block_pattern)}
    return {"prefix": new_prefix, "blocks": new_blocks}


def load_prefill(cfg: ModelConfig, state, cache, slot, table_row,
                 block_size: int):
    """Scatter one sequence's prefill cache (lm.prefill, batch=1) into the
    paged slot state.

    The engine's admission path fuses prefill and this scatter in
    `lm.prefill_paged`; this standalone per-sequence loader is the
    reference oracle it is tested against (tests/test_serving.py) and
    the library route for seeding paged state outside the engine.

    `slot` (int32 scalar) and `table_row` ((max_blocks,) int32) are traced,
    so one jitted instance serves every slot; the prompt length is static
    from `cache` leaf shapes. Attention K/V of prompt position p lands in
    physical block table_row[p // block_size], offset p % block_size;
    recurrent final states land at the slot index.
    """
    def attn_positions(n_tok):
        pos = jnp.arange(n_tok)
        return table_row[pos // block_size], pos % block_size

    def load_layer(kind, st, ca, stacked):
        if kind in ATTN_KINDS:
            # ca k/v: (B=1, P, KV, hd), stacked: (n_super, 1, P, KV, hd)
            n_tok = ca["k"].shape[2] if stacked else ca["k"].shape[1]
            blk, off = attn_positions(n_tok)
            if stacked:
                return {"k": st["k"].at[:, blk, off].set(ca["k"][:, 0]),
                        "v": st["v"].at[:, blk, off].set(ca["v"][:, 0])}
            return {"k": st["k"].at[blk, off].set(ca["k"][0]),
                    "v": st["v"].at[blk, off].set(ca["v"][0])}
        if stacked:
            return jax.tree.map(lambda s, c: s.at[:, slot].set(c[:, 0]),
                                st, ca)
        return jax.tree.map(lambda s, c: s.at[slot].set(c[0]), st, ca)

    new_prefix = [load_layer(kind, st, ca, False)
                  for kind, st, ca in zip(cfg.prefix_pattern,
                                          state["prefix"], cache["prefix"])]
    new_blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        key = f"p{pi}"
        new_blocks[key] = load_layer(kind, state["blocks"][key],
                                     cache["blocks"][key], True)
    return {"prefix": new_prefix, "blocks": new_blocks}
