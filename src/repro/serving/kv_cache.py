"""Paged KV cache: fixed-size physical blocks + per-sequence block tables.

The seed decode path allocates (batch, max_len, KV, hd) per layer — memory
scales with the worst case whether or not tokens exist. Here every layer's
cache is a pool of `num_blocks` blocks of `block_size` tokens; a sequence
occupying `n` tokens holds ceil(n / block_size) blocks, found through its
block-table row. Memory scales with LIVE tokens across all slots — the
serving-side analogue of the paper's hold-a-minibatch memory accounting
(cache capacity is a token budget, not a batch x max_len rectangle).

Block 0 is reserved as the null sink: inactive decode slots point their
table rows at it, so the always-full-batch decode step has somewhere
harmless to write. The allocator never hands it out.

Layer-state layout (mirrors models/lm.init_decode_state):
  attention layers   {"k","v"}: (num_blocks, block_size, KV, hd) pools,
                     stacked layers carry a leading n_super axis;
  recurrent layers   slot-indexed dense state, (num_slots, ...) per leaf —
                     O(num_slots), no paging needed.

Quantized pools (`kv_dtype` "int8" / "fp8") shrink the per-token pool
footprint 2-4x: attention layer dicts gain float32 "k_scale"/"v_scale"
side-tables of shape (num_blocks, block_size, KV) — one max-abs scale per
(token slot, kv head) over head_dim, the `optim/compression.py` quantizer
shape localized per pool slot. Per-slot scales mean every write
(prefill/decode/verify) quantizes independently — no lossy requantization
on incremental decode — and copying a block's (q, scale) pair verbatim is
an exact round-trip (the property the host spill tier relies on). The
default "fp16" maps to cfg.act_dtype, keeping the unquantized path
bit-identical to the pre-quantization layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, lm
# BlockAllocator grew refcounts + the prefix-cache index and moved to its
# own layer; re-exported here for backward compatibility.
from repro.serving.block_manager import NULL_BLOCK, BlockAllocator  # noqa: F401

# block kinds whose KV lives in the paged pools (canonical set —
# the engine's prefix-cache gate and copy_block both key off it)
ATTN_KINDS = ("attn", "attn_local", "moe")

# pool precisions: "fp16" is the activation dtype (bit-identical default);
# the quantized modes carry per-slot scale side-tables.
KV_DTYPES = ("fp16", "int8", "fp8")


def pool_dtype(cfg: ModelConfig, kv_dtype: str = "fp16") -> jnp.dtype:
    """Element dtype of the K/V pools for a kv_dtype knob."""
    if kv_dtype == "fp16":
        return cfg.act_dtype
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    if kv_dtype == "fp8":
        fp8 = getattr(jnp, "float8_e4m3fn", None)
        if fp8 is None:
            raise ValueError(
                "kv_dtype='fp8' needs jax.numpy.float8_e4m3fn, which this "
                "jax build does not provide; use 'int8' or 'fp16'")
        return jnp.dtype(fp8)
    raise ValueError(f"unknown kv_dtype {kv_dtype!r}; expected {KV_DTYPES}")


def quantized(kv_dtype: str) -> bool:
    return kv_dtype != "fp16"


def init_paged_state(cfg: ModelConfig, num_slots: int, num_blocks: int,
                     block_size: int, kv_dtype: str = "fp16"):
    """Paged decode-state pytree (same layer tree as init_decode_state)."""
    dt = cfg.act_dtype
    pool_dt = pool_dtype(cfg, kv_dtype)

    def layer_state(kind):
        if kind in ATTN_KINDS:
            shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
            st = {"k": jnp.zeros(shape, pool_dt),
                  "v": jnp.zeros(shape, pool_dt)}
            if quantized(kv_dtype):
                st["k_scale"] = jnp.zeros(shape[:3], jnp.float32)
                st["v_scale"] = jnp.zeros(shape[:3], jnp.float32)
            return st
        return lm._init_block_state(cfg, kind, num_slots, 0, dt)

    state = {"prefix": [layer_state(k) for k in cfg.prefix_pattern]}
    blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        one = layer_state(kind)
        blocks[f"p{pi}"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_super,) + x.shape, x.dtype), one)
    state["blocks"] = blocks
    return state


def _n_attn_layers(cfg: ModelConfig) -> int:
    return (sum(k in ATTN_KINDS for k in cfg.prefix_pattern)
            + cfg.n_super * sum(k in ATTN_KINDS for k in cfg.block_pattern))


def paged_bytes(cfg: ModelConfig, num_blocks: int, block_size: int,
                kv_dtype: str = "fp16") -> int:
    """Attention-cache bytes of the pool (the memory the paging bounds),
    computed from the actual pool dtype plus the scale side-tables."""
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * pool_dtype(cfg,
                                                             kv_dtype).itemsize
    if quantized(kv_dtype):
        per_tok += 2 * cfg.n_kv_heads * 4      # f32 scale per (slot, head)
    return _n_attn_layers(cfg) * num_blocks * block_size * per_tok


def block_bytes(cfg: ModelConfig, block_size: int,
                kv_dtype: str = "fp16") -> int:
    """Bytes one physical block occupies across all attention pools (the
    host-tier payload size per demoted block)."""
    return paged_bytes(cfg, 1, block_size, kv_dtype)


def copy_block(cfg: ModelConfig, state, src, dst):
    """Copy one physical block in every attention pool (src/dst are
    traced int32 block ids, so one jitted instance serves all copies).
    The copy-on-write primitive: a sequence that must write into a shared
    prompt block gets a private copy first (see serving/scheduler.py).
    Every pool leaf is copied — quantized pools carry their scale tables
    with the payload, so a COW copy round-trips exactly.
    Recurrent slot state is untouched — it is per-slot, never shared."""

    def copy_layer(kind, st, stacked):
        if kind not in ATTN_KINDS:
            return st
        if stacked:
            return {n: a.at[:, dst].set(a[:, src]) for n, a in st.items()}
        return {n: a.at[dst].set(a[src]) for n, a in st.items()}

    new_prefix = [copy_layer(kind, st, False)
                  for kind, st in zip(cfg.prefix_pattern, state["prefix"])]
    new_blocks = {f"p{pi}": copy_layer(kind, state["blocks"][f"p{pi}"], True)
                  for pi, kind in enumerate(cfg.block_pattern)}
    return {"prefix": new_prefix, "blocks": new_blocks}


def load_prefill(cfg: ModelConfig, state, cache, slot, table_row,
                 block_size: int):
    """Scatter one sequence's prefill cache (lm.prefill, batch=1) into the
    paged slot state.

    The engine's admission path fuses prefill and this scatter in
    `lm.prefill_paged`; this standalone per-sequence loader is the
    reference oracle it is tested against (tests/test_serving.py) and
    the library route for seeding paged state outside the engine.

    `slot` (int32 scalar) and `table_row` ((max_blocks,) int32) are traced,
    so one jitted instance serves every slot; the prompt length is static
    from `cache` leaf shapes. Attention K/V of prompt position p lands in
    physical block table_row[p // block_size], offset p % block_size;
    recurrent final states land at the slot index. Quantized pools
    quantize on landing, scattering (q, scale) per token slot.
    """
    def attn_positions(n_tok):
        pos = jnp.arange(n_tok)
        return table_row[pos // block_size], pos % block_size

    def load_layer(kind, st, ca, stacked):
        if kind in ATTN_KINDS:
            # ca k/v: (B=1, P, KV, hd), stacked: (n_super, 1, P, KV, hd)
            n_tok = ca["k"].shape[2] if stacked else ca["k"].shape[1]
            blk, off = attn_positions(n_tok)
            k, v = ca["k"], ca["v"]
            if "k_scale" in st:
                k, sk = attention.quantize_kv(k, st["k"].dtype)
                v, sv = attention.quantize_kv(v, st["v"].dtype)
            if stacked:
                out = {"k": st["k"].at[:, blk, off].set(k[:, 0]),
                       "v": st["v"].at[:, blk, off].set(v[:, 0])}
                if "k_scale" in st:
                    out["k_scale"] = st["k_scale"].at[:, blk, off].set(
                        sk[:, 0])
                    out["v_scale"] = st["v_scale"].at[:, blk, off].set(
                        sv[:, 0])
                return out
            out = {"k": st["k"].at[blk, off].set(k[0]),
                   "v": st["v"].at[blk, off].set(v[0])}
            if "k_scale" in st:
                out["k_scale"] = st["k_scale"].at[blk, off].set(sk[0])
                out["v_scale"] = st["v_scale"].at[blk, off].set(sv[0])
            return out
        if stacked:
            return jax.tree.map(lambda s, c: s.at[:, slot].set(c[:, 0]),
                                st, ca)
        return jax.tree.map(lambda s, c: s.at[slot].set(c[0]), st, ca)

    new_prefix = [load_layer(kind, st, ca, False)
                  for kind, st, ca in zip(cfg.prefix_pattern,
                                          state["prefix"], cache["prefix"])]
    new_blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        key = f"p{pi}"
        new_blocks[key] = load_layer(kind, state["blocks"][key],
                                     cache["blocks"][key], True)
    return {"prefix": new_prefix, "blocks": new_blocks}


# ----------------------------------------------------------------------------
# host-tier payload movement: gather blocks out of / scatter back into the
# attention pools. Payload leaves all carry the block-width axis FIRST
# (stacked layers are transposed to (W, n_super, bs, KV, hd)) so host-side
# batching is a uniform axis-0 concatenate regardless of layer structure.
# ----------------------------------------------------------------------------

def gather_blocks(cfg: ModelConfig, state, ids):
    """Gather physical blocks `ids` ((W,) int32, traced) from every
    attention pool. Returns a pytree of (W, ...) leaves; recurrent layers
    contribute empty subtrees (their state is per-slot, never demoted)."""

    def g(kind, st, stacked):
        if kind not in ATTN_KINDS:
            return {}
        if stacked:
            return {n: jnp.moveaxis(a[:, ids], 1, 0) for n, a in st.items()}
        return {n: a[ids] for n, a in st.items()}

    prefix = [g(kind, st, False)
              for kind, st in zip(cfg.prefix_pattern, state["prefix"])]
    blocks = {f"p{pi}": g(kind, state["blocks"][f"p{pi}"], True)
              for pi, kind in enumerate(cfg.block_pattern)}
    return {"prefix": prefix, "blocks": blocks}


def scatter_blocks(cfg: ModelConfig, state, ids, payload):
    """Scatter a gather_blocks-shaped payload back into the pools at
    `ids`. Padded entries may target NULL_BLOCK (the null sink)."""

    def s(kind, st, pa, stacked):
        if kind not in ATTN_KINDS:
            return st
        if stacked:
            return {n: st[n].at[:, ids].set(
                jnp.moveaxis(pa[n], 0, 1).astype(st[n].dtype)) for n in st}
        return {n: st[n].at[ids].set(pa[n].astype(st[n].dtype)) for n in st}

    new_prefix = [s(kind, st, pa, False)
                  for kind, st, pa in zip(cfg.prefix_pattern,
                                          state["prefix"], payload["prefix"])]
    new_blocks = {f"p{pi}": s(kind, state["blocks"][f"p{pi}"],
                              payload["blocks"][f"p{pi}"], True)
                  for pi, kind in enumerate(cfg.block_pattern)}
    return {"prefix": new_prefix, "blocks": new_blocks}
