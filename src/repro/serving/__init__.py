"""Continuous-batching serving subsystem, decomposed into three layers.

`engine.ServingEngine` is a thin facade over:

  `scheduler.Scheduler`        queue, bucketed admission, lifecycle,
                               eviction, copy-on-write orchestration,
                               draft propose / accept / rollback
  `block_manager.BlockAllocator`
                               refcounted physical KV blocks + content-
                               hash prefix index (shared prompt blocks)
  `runner.ModelRunner`         jitted bucketed batched prefill / paged
                               decode / multi-token verify dispatch,
                               device block tables

Requests enter a queue; the scheduler admits same-bucket groups in one
padded prefill dispatch; finished sequences are evicted and replaced
mid-flight so the decode batch stays full under sustained load. Cache
memory scales with live tokens (blocks), not batch x max_len, and
identical prompt prefixes share physical blocks by refcount. With
`speculate=K`, per-slot n-gram proposers (`draft.py`) draft up to K
tokens that one bucketed verify dispatch checks; the longest agreeing
prefix plus one bonus token is accepted and rejected drafts roll back
(positions for attention, snapshots for recurrent state, block claims
for the allocator) — greedy output is bit-identical to `generate()`.
"""
from repro.serving.block_manager import BlockAllocator, PrefixMatch
from repro.serving.bucketing import next_pow2, pick_bucket, pow2_buckets
from repro.serving.draft import NGramProposer, make_proposer
from repro.serving.engine import (Completion, Request, ServingEngine,
                                  repetitive_requests,
                                  shared_prefix_requests, summarize,
                                  synthetic_requests)
from repro.serving.kv_cache import init_paged_state
from repro.serving.runner import ModelRunner
from repro.serving.scheduler import Scheduler

__all__ = ["ServingEngine", "Request", "Completion", "synthetic_requests",
           "shared_prefix_requests", "repetitive_requests", "summarize",
           "BlockAllocator", "PrefixMatch", "ModelRunner", "Scheduler",
           "init_paged_state", "NGramProposer", "make_proposer",
           "next_pow2", "pick_bucket", "pow2_buckets"]
