"""Continuous-batching serving subsystem, decomposed into three layers.

`engine.ServingEngine` is a thin facade over:

  `scheduler.Scheduler`        queue, bucketed admission, lifecycle,
                               eviction, copy-on-write orchestration,
                               draft propose / accept / rollback
  `block_manager.BlockAllocator`
                               refcounted physical KV blocks + content-
                               hash prefix index (shared prompt blocks)
  `runner.ModelRunner`         jitted bucketed batched prefill / paged
                               decode / multi-token verify dispatch,
                               device block tables

Requests enter a queue; the scheduler admits same-bucket groups in one
padded prefill dispatch; finished sequences are evicted and replaced
mid-flight so the decode batch stays full under sustained load. Cache
memory scales with live tokens (blocks), not batch x max_len, and
identical prompt prefixes share physical blocks by refcount. Every
request carries its own `SamplingParams` (`sampling.py`): temperature /
top-k / top-p / per-request seed / stop sequences ride through the
jitted dispatches as data, randomness is position-keyed
(fold_in(PRNGKey(seed), pos)), so one batch freely mixes greedy,
sampled, and speculative-sampled lanes and a request's realization is
independent of batch composition. With `speculate=K`, per-slot n-gram
proposers (`draft.py`) draft up to K tokens that one bucketed verify
dispatch checks; greedy lanes accept the longest argmax-agreeing
prefix plus one bonus token (output bit-identical to `generate()`),
sampled lanes run Leviathan accept/reject with residual resampling
(target distribution preserved exactly); rejected drafts roll back
(positions for attention, snapshots for recurrent state, block claims
for the allocator).

Above the engine sits the CLUSTER layer (`replica.py` / `router.py`):
`Replica` wraps one full engine stack (its own device pools, prefix
cache, everything replica-local) behind occupancy/affinity probes, and
`Router` fronts a cluster-wide queue with pluggable placement —
round-robin, least-loaded (slot+queue occupancy), prefix-affinity (the
BlockAllocator `match_prefix` content-hash probe) — plus backpressure,
sticky placement, drain/failover, and cluster-level run()/stream()
that merge per-replica streams. Outputs are bit-identical to a
single-replica run for every policy and replica count (the
batch-composition-independence guarantee, one level up).

Cross-cutting: `observability.py` — a zero-cost-when-off recorder
(metrics registry + request-lifecycle tracing + Chrome/Perfetto
trace_event and metrics-dump exporters) that every layer publishes
into. Pass `obs=Observability()` to ServingEngine / Replica / Router;
the default NULL_OBS records nothing and adds no work to the hot path,
and outputs are bit-identical either way.
"""
from repro.serving.block_manager import BlockAllocator, PrefixMatch
from repro.serving.bucketing import next_pow2, pick_bucket, pow2_buckets
from repro.serving.draft import NGramProposer, make_proposer
from repro.serving.engine import (Completion, Request, ServingEngine,
                                  multi_tenant_requests,
                                  repetitive_requests,
                                  shared_prefix_requests, summarize,
                                  synthetic_requests)
from repro.serving.kv_cache import init_paged_state
from repro.serving.observability import (NULL_OBS, MetricsRegistry,
                                         Observability, export_metrics,
                                         export_trace, metrics_dump,
                                         to_perfetto,
                                         validate_metrics_dump,
                                         validate_trace_events)
from repro.serving.replica import Replica, ReplicaSnapshot
from repro.serving.router import (POLICIES, Router, normalize_policy,
                                  summarize_cluster)
from repro.serving.runner import ModelRunner
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerStats, StreamEvent

__all__ = ["ServingEngine", "Request", "Completion", "SamplingParams",
           "StreamEvent", "SchedulerStats", "synthetic_requests",
           "shared_prefix_requests", "repetitive_requests",
           "multi_tenant_requests", "summarize",
           "Replica", "ReplicaSnapshot", "Router", "POLICIES",
           "normalize_policy", "summarize_cluster",
           "BlockAllocator", "PrefixMatch", "ModelRunner", "Scheduler",
           "init_paged_state", "NGramProposer", "make_proposer",
           "next_pow2", "pick_bucket", "pow2_buckets",
           "Observability", "NULL_OBS", "MetricsRegistry", "to_perfetto",
           "metrics_dump", "export_trace", "export_metrics",
           "validate_trace_events", "validate_metrics_dump"]
