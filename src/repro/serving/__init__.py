"""Continuous-batching serving subsystem.

`engine.ServingEngine` — slot-scheduled continuous batching over a paged
KV cache (`kv_cache`): requests enter a queue, the scheduler admits them
into free decode slots, finished sequences are evicted and replaced
mid-flight so the decode batch stays full under sustained load. Cache
memory scales with live tokens (blocks), not batch x max_len.
"""
from repro.serving.engine import (Completion, Request, ServingEngine,
                                  summarize, synthetic_requests)
from repro.serving.kv_cache import BlockAllocator, init_paged_state

__all__ = ["ServingEngine", "Request", "Completion", "synthetic_requests",
           "summarize", "BlockAllocator", "init_paged_state"]
