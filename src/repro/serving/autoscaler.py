"""Autoscaler: the policy layer that closes the telemetry->control loop.

The sixth layer of the serving stack (autoscaler -> router -> replicas
-> scheduler -> block manager -> runner), and the first one that ACTS
on the signals the observability layer records instead of only
recording them. It consumes the same per-replica `SchedulerStats`
occupancy feed that `Observability.sample_stats` publishes as the
metrics time series (queue depth, slot occupancy, block supply on the
shared cluster clock) and drives replica lifecycle through the router:

  scale-out   sustained per-replica queue depth above `queue_high` for
              `high_window_s` seconds -> activate a replica: first
              cancel a drain in progress, else take one from the
              STANDBY pool (a previously-built engine stack whose jit
              caches are still warm — activation costs one list append,
              not a compile), else call the `spawn` factory. Mid-run
              joiners adopt the cluster clock without touching shared
              telemetry (Router.add_replica).
  scale-in    sustained per-replica load (queue + active slots) at or
              below `queue_low` for `low_window_s` seconds while more
              than `min_replicas` are enabled -> drain the least-loaded
              replica: `Router.disable` requeues its unadmitted
              requests onto the cluster queue; lanes already running
              finish where they are (preempted lanes' resume requests
              stay — their cached KV is replica-local).
  reclaim     a draining replica that has fully emptied is removed from
              the router (its completions are held for `run()`), its
              prefix cache dropped, and its engine stack parked back in
              the standby pool, jit-warm for the next burst.

Hysteresis comes from the separate high/low thresholds plus the
sustain windows; `cooldown_s` spaces decisions so one burst cannot
flap the cluster. Every decision lands as observability counters
(`autoscaler_scale_out_total` / `autoscaler_scale_in_total` /
`autoscaler_reclaimed_total`), a replica-count gauge, and a trace
instant on the control track — the ci autoscale smoke asserts them
from the exported metrics dump.

`AutoscaleController` is the pure decision core: feed it
(t, queue_depth, active_slots, n_replicas) samples — live snapshots or
a recorded stats series — and it returns 'out' / 'in' / None. The
policy unit tests drive it over synthetic series; `Autoscaler.tick`
wires it to a live Router.

Because every request's realization is batch-composition independent,
scaling events never change outputs: an autoscaled run is bit-identical
to a fixed-size run of the same workload (gated by serving_bench and
the ci autoscale smoke).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

from repro.serving.observability import NULL_OBS, Observability
from repro.serving.replica import Replica

# trace track for control-plane instants (request lanes use slot ids,
# dispatches use DISPATCH_TID=99 — keep clear of both)
CONTROL_TID = 90


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Knobs for the scale-out/scale-in state machine.

    queue_high     per-enabled-replica QUEUE depth at or above which
                   pressure accumulates toward a scale-out
    queue_low      per-enabled-replica LOAD (queue + active slots) at or
                   below which idleness accumulates toward a scale-in;
                   keep queue_low < queue_high + slots or the fresh
                   post-scale-out equilibrium re-triggers a scale-in
                   (that gap IS the hysteresis band)
    high_window_s  seconds the high signal must sustain before scaling
                   out (absorbs one-step blips)
    low_window_s   seconds the low signal must sustain before scaling
                   in (longer than high: adding capacity is cheap and
                   urgent, removing it is neither)
    cooldown_s     minimum seconds between any two decisions
    """
    min_replicas: int = 1
    max_replicas: int = 4
    queue_high: float = 2.0
    queue_low: float = 1.0
    high_window_s: float = 0.1
    low_window_s: float = 0.4
    cooldown_s: float = 0.25

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.queue_low >= self.queue_high:
            raise ValueError("need queue_low < queue_high (hysteresis)")


class AutoscaleController:
    """The pure policy core: a hysteresis + cooldown state machine over
    an occupancy sample stream. Stateless about WHAT a replica is —
    testable over synthetic stats series, replayable over a recorded
    metrics dump."""

    kind = "queue-depth"

    def __init__(self, policy: AutoscalePolicy):
        self.policy = policy
        self.reset()

    def reset(self) -> None:
        self._above_since: Optional[float] = None
        self._below_since: Optional[float] = None
        self._last_decision = float("-inf")

    def observe(self, t: float, queue_depth: float, active_slots: float,
                n_replicas: int) -> Optional[str]:
        """Feed one occupancy sample (cluster totals at time `t`,
        monotone across calls); returns 'out', 'in', or None. A
        decision consumes its accumulated window, so the signal must
        sustain AGAIN before the next same-direction decision — with
        the cooldown, that is the no-flapping guarantee."""
        p = self.policy
        n = max(int(n_replicas), 1)
        q_per = queue_depth / n
        load_per = (queue_depth + active_slots) / n
        if q_per >= p.queue_high:
            if self._above_since is None:
                self._above_since = t
        else:
            self._above_since = None
        if load_per <= p.queue_low:
            if self._below_since is None:
                self._below_since = t
        else:
            self._below_since = None
        cool = (t - self._last_decision) >= p.cooldown_s
        if (self._above_since is not None
                and n_replicas < p.max_replicas and cool
                and t - self._above_since >= p.high_window_s):
            self._last_decision = t
            self._above_since = None
            return "out"
        if (self._below_since is not None
                and n_replicas > p.min_replicas and cool
                and t - self._below_since >= p.low_window_s):
            self._last_decision = t
            self._below_since = None
            return "in"
        return None


class Autoscaler:
    """Elastic replica lifecycle over a Router (see module docstring).

    standby   pre-built Replicas to activate on scale-out (jit-warm —
              the recommended source; build max_replicas stacks up
              front and hand the router only min_replicas)
    spawn     optional factory `replica_id -> Replica` used when the
              standby pool is empty (a cold spawn pays jit compiles on
              its first dispatches — fine for capacity, bad for p99)
    controller  alternative decision core implementing the same
              `observe(t, queue_depth, active_slots, n_replicas)` /
              `reset()` contract — e.g. `slo.SLOSignal`, which scales
              on TTFT burn rate instead of the queue-depth bands.
              Default: `AutoscaleController(policy)`.

    Construction attaches to the router: `Router._drive` ticks the
    autoscaler once per sweep and calls `begin_run` at run start.
    """

    def __init__(self, router, *, policy: Optional[AutoscalePolicy] = None,
                 standby: Sequence[Replica] = (),
                 spawn: Optional[Callable[[int], Replica]] = None,
                 controller=None,
                 obs: Observability = NULL_OBS):
        self.router = router
        self.policy = policy or AutoscalePolicy()
        self.controller = (controller if controller is not None
                           else AutoscaleController(self.policy))
        self._standby: List[Replica] = list(standby)
        self._spawn = spawn
        ids = [r.replica_id for r in router.replicas]
        ids += [r.replica_id for r in self._standby]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids {sorted(ids)}")
        self._next_id = max(ids) + 1
        self._draining: set = set()    # replica ids disabled, emptying
        self._added: set = set()       # ids the autoscaler activated
        self._obs = obs or NULL_OBS
        self._c_out = self._obs.counter("autoscaler_scale_out_total")
        self._c_in = self._obs.counter("autoscaler_scale_in_total")
        self._c_reclaimed = self._obs.counter("autoscaler_reclaimed_total")
        self._g_replicas = self._obs.gauge("autoscaler_replicas_gauge")
        self.scale_out_events = 0
        self.scale_in_events = 0
        self.reclaims = 0
        self.skipped_scale_outs = 0    # decision with no source to add
        self.events: List[dict] = []   # [{'t','event','replica'}, ...]
        router.autoscaler = self

    # -- lifecycle ---------------------------------------------------------

    def begin_run(self, t0: float) -> None:
        """Per-run reset, called by Router._drive BEFORE the base
        replicas' begin_run: retire every autoscaled replica to standby
        (clean telemetry, cold prefix cache, aligned clock — registry
        resets here are pre-run, so nothing is lost), cancel drains,
        re-enable the base set, and zero the event log."""
        for rid in sorted(self._added):
            try:
                rep = self.router.remove_replica(rid)
            except (KeyError, RuntimeError):
                continue              # already gone, or still has work
            rep.begin_run(t0)
            rep.reset_prefix_cache()
            self._standby.append(rep)
        self._added.clear()
        self._draining.clear()
        for rep in self.router.replicas:
            rep.enabled = True
        for rep in self._standby:
            rep.begin_run(t0)
            rep.reset_prefix_cache()
        self.controller.reset()
        self.scale_out_events = 0
        self.scale_in_events = 0
        self.reclaims = 0
        self.skipped_scale_outs = 0
        self.events = []

    # -- the control loop --------------------------------------------------

    def _enabled(self) -> List[Replica]:
        return [r for r in self.router.replicas if r.enabled]

    def tick(self, now: float) -> Optional[str]:
        """One control-loop iteration on the cluster clock: reclaim any
        drained replicas, sample occupancy, act on the controller's
        decision. Returns the action taken ('out'/'in'/None)."""
        for rid in sorted(self._draining):
            rep = next((r for r in self.router.replicas
                        if r.replica_id == rid), None)
            if rep is None:
                self._draining.discard(rid)
                continue
            if rep.has_work:
                continue
            self.router.remove_replica(rid)
            rep.reset_prefix_cache()
            self._standby.append(rep)
            self._draining.discard(rid)
            self._added.discard(rid)
            self.reclaims += 1
            self._c_reclaimed.inc()
            self._event(now, "reclaim", rid)
        enabled = self._enabled()
        qd = len(self.router._queue) + sum(
            r.snapshot().queue_depth for r in enabled)
        act = sum(r.snapshot().active_slots for r in enabled)
        decision = self.controller.observe(now, qd, act, len(enabled))
        if decision == "out":
            return self._scale_out(now)
        if decision == "in":
            return self._scale_in(now)
        return None

    def _scale_out(self, now: float) -> Optional[str]:
        if self._draining:
            # cheapest capacity: cancel a drain in progress
            rid = min(self._draining)
            self.router.enable(rid)
            self._draining.discard(rid)
        elif self._standby:
            rep = self._standby.pop()
            self.router.add_replica(rep)
            self._added.add(rep.replica_id)
            rid = rep.replica_id
        elif self._spawn is not None:
            rep = self._spawn(self._next_id)
            self._next_id += 1
            self.router.add_replica(rep)
            self._added.add(rep.replica_id)
            rid = rep.replica_id
        else:
            self.skipped_scale_outs += 1
            return None
        self.scale_out_events += 1
        self._c_out.inc()
        self._g_replicas.set(len(self._enabled()))
        self._event(now, "scale-out", rid)
        return "out"

    def _scale_in(self, now: float) -> Optional[str]:
        # drain the least-loaded enabled replica; prefer one the
        # autoscaler added (the base set is the steady-state cluster)
        cands = [r for r in self._enabled()
                 if r.replica_id not in self._draining]
        if len(cands) <= self.policy.min_replicas:
            return None
        added = [r for r in cands if r.replica_id in self._added]
        pool = added or cands
        victim = min(pool, key=lambda r: (r.snapshot().load,
                                          -r.replica_id))
        self.router.disable(victim.replica_id)
        self._draining.add(victim.replica_id)
        self.scale_in_events += 1
        self._c_in.inc()
        self._g_replicas.set(len(self._enabled()))
        self._event(now, "scale-in", victim.replica_id)
        return "in"

    def _event(self, now: float, kind: str, rid: int) -> None:
        self.events.append({"t": round(now, 4), "event": kind,
                            "replica": rid})
        if self._obs.enabled:
            self._obs.instant(CONTROL_TID, kind, "autoscale", now,
                              replica=rid,
                              enabled=len(self._enabled()),
                              standby=len(self._standby))

    # -- reporting ---------------------------------------------------------

    def summary(self) -> dict:
        """The record a bench embeds: policy, event counts, event log."""
        return {
            "policy": dataclasses.asdict(self.policy),
            "signal": getattr(self.controller, "kind", "queue-depth"),
            "enabled_replicas": len(self._enabled()),
            "standby_replicas": len(self._standby),
            "draining_replicas": len(self._draining),
            "scale_out_events": self.scale_out_events,
            "scale_in_events": self.scale_in_events,
            "reclaims": self.reclaims,
            "skipped_scale_outs": self.skipped_scale_outs,
            "events": self.events,
        }
