"""Refcounted block manager: physical KV blocks, prefix sharing, COW.

The middle layer of the serving engine (scheduler -> block manager ->
runner). It owns every host-side fact about the physical block pool:

  * a free-list allocator over blocks 1..num_blocks-1 (block 0 is the
    reserved null sink idle decode lanes write into),
  * a reference count per live block, so immutable prompt-prefix blocks
    can be shared by many sequences at once,
  * a content-hash index over FULL immutable prompt blocks, keyed by a
    chain hash (block tokens + everything before them), so two prompts
    that share a prefix resolve to the same physical blocks,
  * copy-on-write policy: `is_writable` says whether a sequence may
    write a block in place (it owns the only reference AND the block is
    not published in the index); otherwise the scheduler must copy the
    block into a private one first.

Freed blocks that are still in the index are not returned to the free
list immediately: they park in an LRU "cached-free" pool and keep their
contents, so a later request with the same prefix still hits — the
serving-side analogue of the paper's hold-state-to-avoid-recomputation
tradeoff. Allocation prefers truly-free blocks and evicts cached-free
blocks LRU-first only under pressure, unregistering them.

Host spill tier: with `host_cache_blocks > 0` and fetch/store callbacks
(ModelRunner.fetch_block / upload_blocks), eviction does not discard a
cached block's payload — it is DEMOTED to a capacity-bounded LRU of host
(numpy) payloads keyed by the same content-hash chain keys. A later
match_prefix that runs off the device chain walks the host continuation,
re-allocates device blocks (only from the truly-free list, never by
evicting — the current match may pin cached blocks), uploads the payloads
batched, and re-registers them under their original keys as cached-free
blocks — so the existing share/COW machinery sees an ordinary prefix hit.
Quantized pools demote (q, scale) verbatim, so a round-trip is exact.

Invariants (property-tested in tests/test_block_manager.py):
  * refcounts are never negative; decref of a dead block raises,
  * a block is never simultaneously free and referenced,
  * free + cached-free + live == num_blocks - 1 (conservation),
  * shared (refcount > 1) or indexed blocks are never `is_writable`,
  * alloc returns None, never a partial grant, when short.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NULL_BLOCK = 0

_ROOT = ("root",)  # parent key of a prompt's first block


class PrefixMatch:
    """Result of matching a prompt against the prefix index.

    full_blocks     physical blocks covering whole 'block_size' chunks
    partial_block   a cached block whose first `partial_len` tokens match
                    the prompt's remainder (the first divergent block —
                    shared copy-on-write), or None
    partial_len     matched tokens inside partial_block
    spilled_tokens  tokens whose blocks sit in the HOST tier continuation
                    past the device chain (only set by probe-mode
                    match_prefix(promote=False); a promoting match revives
                    them into full_blocks instead)
    """

    __slots__ = ("full_blocks", "partial_block", "partial_len",
                 "spilled_tokens")

    def __init__(self, full_blocks: List[int],
                 partial_block: Optional[int], partial_len: int,
                 spilled_tokens: int = 0):
        self.full_blocks = full_blocks
        self.partial_block = partial_block
        self.partial_len = partial_len
        self.spilled_tokens = spilled_tokens

    def tokens(self, block_size: int) -> int:
        return len(self.full_blocks) * block_size + self.partial_len

    def blocks(self) -> List[int]:
        out = list(self.full_blocks)
        if self.partial_block is not None:
            out.append(self.partial_block)
        return out


class BlockAllocator:
    """Refcounted free-list allocator with a prompt-prefix content index.

    `block_size` is only needed for the prefix-cache methods
    (match_prefix / register_prefix); a plain allocator can pass 0.

    `host_cache_blocks` > 0 enables the host spill tier; `fetch_block`
    (block id -> host payload) and `store_blocks` (ids, payloads -> None)
    are the device<->host movement callbacks, normally
    ModelRunner.fetch_block / ModelRunner.upload_blocks.
    """

    def __init__(self, num_blocks: int, block_size: int = 0,
                 obs=None, host_cache_blocks: int = 0,
                 fetch_block=None, store_blocks=None):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        from repro.serving.observability import NULL_OBS
        self._obs = obs or NULL_OBS
        self._c_allocs = self._obs.counter("blocks_allocated_total")
        self._c_evictions = self._obs.counter("cache_evictions_total")
        self._c_demotions = self._obs.counter("host_demotions_total")
        self._c_revivals = self._obs.counter("host_revivals_total")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        # prefix index state (all keyed by physical block id)
        self._index: Dict[int, int] = {}       # chain key -> block
        self._key: Dict[int, int] = {}         # block -> chain key
        self._parent: Dict[int, Tuple] = {}    # block -> parent chain key
        self._tokens: Dict[int, Tuple[int, ...]] = {}
        self._children: Dict[Tuple, set] = {}  # parent key -> {blocks}
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU ref==0
        # host spill tier: chain key -> (parent key, chunk, payload), LRU
        self.host_cache_blocks = int(host_cache_blocks)
        self._fetch = fetch_block
        self._store = store_blocks
        self._host: "OrderedDict[int, Tuple]" = OrderedDict()
        # telemetry
        self.cache_evictions = 0
        self.host_demotions = 0
        self.host_revivals = 0

    # ------------------------------------------------------------------
    # refcounted alloc / free
    # ------------------------------------------------------------------

    @property
    def num_free(self) -> int:
        """Allocatable blocks (truly free + evictable cached-free)."""
        return len(self._free) + len(self._cached)

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    @property
    def num_spilled(self) -> int:
        """Blocks currently held in the host spill tier."""
        return len(self._host)

    @property
    def num_indexed(self) -> int:
        """Blocks currently published in the prefix index (live shared
        blocks + cached-free ones) — how much reusable prefix the pool
        holds, the telemetry behind the router's affinity signal."""
        return len(self._key)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n private blocks (refcount 1), or None if short. Evicts
        cached-free blocks LRU-first under pressure — never a partial
        grant."""
        if n < 0:
            raise ValueError(n)
        if n > self.num_free:
            return None
        blocks = []
        for _ in range(n):
            if not self._free:
                victim, _ = self._cached.popitem(last=False)  # LRU
                self._evict(victim, demote=True)
                self._free.append(victim)
                self.cache_evictions += 1
                self._c_evictions.inc()
            b = self._free.pop()
            self._ref[b] = 1
            blocks.append(b)
        self._c_allocs.inc(n)
        return blocks

    def _evict(self, block: int, demote: bool = False) -> None:
        """Unregister `block` and its whole indexed descendant subtree —
        once the chain breaks, descendants can never be matched again.
        Cached-free descendants return to the free list immediately;
        live (still-referenced) ones just lose their registration.
        With `demote` (eviction under allocation pressure), the victim
        and its cached-free descendants spill to the host tier first —
        their chain keys stay intact there, so the subtree remains
        revivable even though the device chain broke."""
        stack = [block]
        while stack:
            b = stack.pop()
            key = self._key.get(b)
            if key is not None:
                stack.extend(self._children.get(key, ()))
                if demote and (b == block or b in self._cached):
                    self._demote(b)
            self._unregister(b)
            if b != block and b in self._cached:
                del self._cached[b]
                self._free.append(b)

    def _demote(self, block: int) -> None:
        """Snapshot a registered block's payload into the host LRU."""
        if (not self.host_cache_blocks or self._fetch is None
                or self._store is None):
            return
        key = self._key.get(block)
        if key is None:
            return
        self._host[key] = (self._parent[block], self._tokens[block],
                           self._fetch(block))
        self._host.move_to_end(key)
        while len(self._host) > self.host_cache_blocks:
            self._host.popitem(last=False)
        self.host_demotions += 1
        self._c_demotions.inc()

    def incref(self, block: int) -> None:
        """Take a reference on a live or cached-free block (sharing)."""
        if block == NULL_BLOCK:
            raise ValueError("cannot reference the reserved null block")
        refs = self._ref.get(block, 0)
        if refs == 0:
            if block not in self._cached:
                raise ValueError(f"incref of free/unowned block {block}")
            del self._cached[block]      # revive from the cached-free pool
        self._ref[block] = refs + 1

    def decref(self, block: int) -> None:
        """Drop a reference; at zero the block goes to the cached-free
        pool if it is indexed, else back to the free list."""
        if block == NULL_BLOCK:
            raise ValueError("cannot free the reserved null block")
        refs = self._ref.get(block, 0)
        if refs <= 0:
            raise ValueError(f"double free / unowned block {block}")
        if refs > 1:
            self._ref[block] = refs - 1
            return
        del self._ref[block]
        if block in self._key:
            self._cached[block] = None
            self._cached.move_to_end(block)
        else:
            self._free.append(block)

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.decref(b)

    def is_writable(self, block: int) -> bool:
        """May the (single) owner write this block in place? False for
        shared blocks and for blocks published in the prefix index —
        those must be copied first (copy-on-write)."""
        if block == NULL_BLOCK:
            return False
        return self._ref.get(block, 0) == 1 and block not in self._key

    # ------------------------------------------------------------------
    # content-hash prefix index
    # ------------------------------------------------------------------

    def _chunk_key(self, parent, chunk: Tuple[int, ...]) -> int:
        return hash((parent, chunk))

    def _lookup(self, parent, chunk: Tuple[int, ...]) -> Optional[int]:
        """Indexed block for (parent chain, exact chunk) or None; hash
        collisions are rejected by comparing the stored tokens."""
        key = self._chunk_key(parent, chunk)
        b = self._index.get(key)
        if b is None:
            return None
        if self._parent.get(b) != parent or self._tokens.get(b) != chunk:
            return None                   # hash collision -> miss
        return b

    def match_prefix(self, tokens: np.ndarray,
                     promote: bool = True) -> PrefixMatch:
        """Longest cached prefix of `tokens`. Full chunks match exactly
        through the chain index; the remainder may partially match the
        first tokens of one more cached block — the first divergent
        block, shareable with COW.

        When the device chain runs out, the host tier is consulted:
        with `promote` (the admission path), a host continuation is
        revived into freshly-allocated device blocks (cached-free,
        re-registered under their original keys) and keeps matching;
        with promote=False (the router's affinity probe) the
        continuation is only counted in `spilled_tokens` — the probe
        takes no references and moves no payloads."""
        if not self.block_size:
            return PrefixMatch([], None, 0)
        toks = [int(t) for t in tokens]
        bs = self.block_size
        parent = _ROOT
        full: List[int] = []
        spilled = 0
        for i in range(len(toks) // bs):
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            b = self._lookup(parent, chunk)
            if b is None and promote and self._revive(parent, toks, i):
                b = self._lookup(parent, chunk)
            if b is None:
                if not promote:
                    spilled = self._host_chain_len(parent, toks, i) * bs
                break
            full.append(b)
            parent = self._chunk_key(parent, chunk)
        if len(full) < len(toks) // bs:   # diverged inside full chunks
            rest = tuple(toks[len(full) * bs:(len(full) + 1) * bs])
        else:
            rest = tuple(toks[len(full) * bs:])
        best, best_len = None, 0
        for cand in self._children.get(parent, ()):
            stored = self._tokens[cand]
            d = 0
            for a, c in zip(rest, stored):
                if a != c:
                    break
                d += 1
            if d > best_len:
                best, best_len = cand, d
        if best is not None and best in full:
            best, best_len = None, 0      # already counted as a full match
        return PrefixMatch(full, best, best_len, spilled)

    def _host_chain_len(self, parent, toks: List[int],
                        start_chunk: int) -> int:
        """Length (in blocks) of the host-tier chain continuing `parent`
        along the prompt's chunks. Read-only (the affinity probe)."""
        bs = self.block_size
        n, p = 0, parent
        for i in range(start_chunk, len(toks) // bs):
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            key = self._chunk_key(p, chunk)
            ent = self._host.get(key)
            if ent is None or ent[0] != p or ent[1] != chunk:
                break
            n += 1
            p = key
        return n

    def _revive(self, parent, toks: List[int], start_chunk: int) -> int:
        """Promote the host-tier chain continuation back into device
        blocks. Allocates only from the truly-free list (never evicts —
        cached-free blocks may belong to the match in progress), uploads
        the payloads in one batched store, and re-registers each block
        under its original chain key as cached-free. Returns #revived."""
        if not self._host or self._store is None:
            return 0
        bs = self.block_size
        found = []                       # (key, parent, chunk, payload)
        p = parent
        for i in range(start_chunk, len(toks) // bs):
            if len(found) >= len(self._free):
                break
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            key = self._chunk_key(p, chunk)
            ent = self._host.get(key)
            if (ent is None or ent[0] != p or ent[1] != chunk
                    or key in self._index):
                break
            found.append((key, p, chunk, ent[2]))
            p = key
        if not found:
            return 0
        blocks = self.alloc(len(found))  # free-list only: n <= len(_free)
        if blocks is None:
            return 0
        self._store(blocks, [f[3] for f in found])
        for b, (key, par, chunk, _) in zip(blocks, found):
            del self._host[key]
            self._index[key] = b
            self._key[b] = key
            self._parent[b] = par
            self._tokens[b] = chunk
            self._children.setdefault(par, set()).add(b)
            self.decref(b)               # indexed -> parks cached-free
            self.host_revivals += 1
            self._c_revivals.inc()
        return len(blocks)

    def share(self, match: PrefixMatch) -> None:
        """Commit a match: take one reference on every matched block
        (revives cached-free blocks). Call before the blocks can be
        evicted by a concurrent alloc."""
        for b in match.blocks():
            self.incref(b)

    def unshare(self, match: PrefixMatch) -> None:
        for b in match.blocks():
            self.decref(b)

    def touch(self, blocks: Sequence[int]) -> None:
        """LRU-touch cached-free blocks (a hit makes them hot)."""
        for b in blocks:
            if b in self._cached:
                self._cached.move_to_end(b)

    def register_prefix(self, tokens: np.ndarray,
                        blocks: Sequence[int]) -> int:
        """Publish a prompt's FULL blocks in the index (after its prefill
        completed). `blocks` are the prompt's physical blocks in table
        order. Chunks already indexed keep their canonical block; the
        sequence's duplicate stays private. Returns #newly indexed."""
        if not self.block_size:
            return 0
        toks = [int(t) for t in tokens]
        bs = self.block_size
        parent = _ROOT
        added = 0
        for i in range(len(toks) // bs):
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            key = self._chunk_key(parent, chunk)
            existing = self._lookup(parent, chunk)
            if existing is None and key not in self._index:
                b = blocks[i]
                if b in self._key:        # already published under a
                    parent = key          # different chain — leave it
                    continue
                self._index[key] = b
                self._key[b] = key
                self._parent[b] = parent
                self._tokens[b] = chunk
                self._children.setdefault(parent, set()).add(b)
                added += 1
            parent = key
        return added

    def _unregister(self, block: int) -> None:
        key = self._key.pop(block, None)
        if key is None:
            return
        self._index.pop(key, None)
        parent = self._parent.pop(block, None)
        self._tokens.pop(block, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(block)
            if not kids:
                del self._children[parent]

    def reset_prefix_cache(self) -> None:
        """Drop the whole index (host tier included); cached-free blocks
        return to the free list. Live shared blocks stay shared (their
        refcounts are untouched) but are no longer discoverable."""
        for b in list(self._key):
            self._unregister(b)
        while self._cached:
            b, _ = self._cached.popitem(last=False)
            self._free.append(b)
        self._host.clear()
