"""Refcounted block manager: physical KV blocks, prefix sharing, COW.

The middle layer of the serving engine (scheduler -> block manager ->
runner). It owns every host-side fact about the physical block pool:

  * a free-list allocator over blocks 1..num_blocks-1 (block 0 is the
    reserved null sink idle decode lanes write into),
  * a reference count per live block, so immutable prompt-prefix blocks
    can be shared by many sequences at once,
  * a content-hash index over FULL immutable prompt blocks, keyed by a
    chain hash (block tokens + everything before them), so two prompts
    that share a prefix resolve to the same physical blocks,
  * copy-on-write policy: `is_writable` says whether a sequence may
    write a block in place (it owns the only reference AND the block is
    not published in the index); otherwise the scheduler must copy the
    block into a private one first.

Freed blocks that are still in the index are not returned to the free
list immediately: they park in an LRU "cached-free" pool and keep their
contents, so a later request with the same prefix still hits — the
serving-side analogue of the paper's hold-state-to-avoid-recomputation
tradeoff. Allocation prefers truly-free blocks and evicts cached-free
blocks LRU-first only under pressure, unregistering them.

Invariants (property-tested in tests/test_block_manager.py):
  * refcounts are never negative; decref of a dead block raises,
  * a block is never simultaneously free and referenced,
  * free + cached-free + live == num_blocks - 1 (conservation),
  * shared (refcount > 1) or indexed blocks are never `is_writable`,
  * alloc returns None, never a partial grant, when short.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NULL_BLOCK = 0

_ROOT = ("root",)  # parent key of a prompt's first block


class PrefixMatch:
    """Result of matching a prompt against the prefix index.

    full_blocks     physical blocks covering whole 'block_size' chunks
    partial_block   a cached block whose first `partial_len` tokens match
                    the prompt's remainder (the first divergent block —
                    shared copy-on-write), or None
    partial_len     matched tokens inside partial_block
    """

    __slots__ = ("full_blocks", "partial_block", "partial_len")

    def __init__(self, full_blocks: List[int],
                 partial_block: Optional[int], partial_len: int):
        self.full_blocks = full_blocks
        self.partial_block = partial_block
        self.partial_len = partial_len

    def tokens(self, block_size: int) -> int:
        return len(self.full_blocks) * block_size + self.partial_len

    def blocks(self) -> List[int]:
        out = list(self.full_blocks)
        if self.partial_block is not None:
            out.append(self.partial_block)
        return out


class BlockAllocator:
    """Refcounted free-list allocator with a prompt-prefix content index.

    `block_size` is only needed for the prefix-cache methods
    (match_prefix / register_prefix); a plain allocator can pass 0.
    """

    def __init__(self, num_blocks: int, block_size: int = 0,
                 obs=None):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        from repro.serving.observability import NULL_OBS
        self._obs = obs or NULL_OBS
        self._c_allocs = self._obs.counter("blocks_allocated_total")
        self._c_evictions = self._obs.counter("cache_evictions_total")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        # prefix index state (all keyed by physical block id)
        self._index: Dict[int, int] = {}       # chain key -> block
        self._key: Dict[int, int] = {}         # block -> chain key
        self._parent: Dict[int, Tuple] = {}    # block -> parent chain key
        self._tokens: Dict[int, Tuple[int, ...]] = {}
        self._children: Dict[Tuple, set] = {}  # parent key -> {blocks}
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU ref==0
        # telemetry
        self.cache_evictions = 0

    # ------------------------------------------------------------------
    # refcounted alloc / free
    # ------------------------------------------------------------------

    @property
    def num_free(self) -> int:
        """Allocatable blocks (truly free + evictable cached-free)."""
        return len(self._free) + len(self._cached)

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    @property
    def num_indexed(self) -> int:
        """Blocks currently published in the prefix index (live shared
        blocks + cached-free ones) — how much reusable prefix the pool
        holds, the telemetry behind the router's affinity signal."""
        return len(self._key)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n private blocks (refcount 1), or None if short. Evicts
        cached-free blocks LRU-first under pressure — never a partial
        grant."""
        if n < 0:
            raise ValueError(n)
        if n > self.num_free:
            return None
        blocks = []
        for _ in range(n):
            if not self._free:
                victim, _ = self._cached.popitem(last=False)  # LRU
                self._evict(victim)
                self._free.append(victim)
                self.cache_evictions += 1
                self._c_evictions.inc()
            b = self._free.pop()
            self._ref[b] = 1
            blocks.append(b)
        self._c_allocs.inc(n)
        return blocks

    def _evict(self, block: int) -> None:
        """Unregister `block` and its whole indexed descendant subtree —
        once the chain breaks, descendants can never be matched again.
        Cached-free descendants return to the free list immediately;
        live (still-referenced) ones just lose their registration."""
        stack = [block]
        while stack:
            b = stack.pop()
            key = self._key.get(b)
            if key is not None:
                stack.extend(self._children.get(key, ()))
            self._unregister(b)
            if b != block and b in self._cached:
                del self._cached[b]
                self._free.append(b)

    def incref(self, block: int) -> None:
        """Take a reference on a live or cached-free block (sharing)."""
        if block == NULL_BLOCK:
            raise ValueError("cannot reference the reserved null block")
        refs = self._ref.get(block, 0)
        if refs == 0:
            if block not in self._cached:
                raise ValueError(f"incref of free/unowned block {block}")
            del self._cached[block]      # revive from the cached-free pool
        self._ref[block] = refs + 1

    def decref(self, block: int) -> None:
        """Drop a reference; at zero the block goes to the cached-free
        pool if it is indexed, else back to the free list."""
        if block == NULL_BLOCK:
            raise ValueError("cannot free the reserved null block")
        refs = self._ref.get(block, 0)
        if refs <= 0:
            raise ValueError(f"double free / unowned block {block}")
        if refs > 1:
            self._ref[block] = refs - 1
            return
        del self._ref[block]
        if block in self._key:
            self._cached[block] = None
            self._cached.move_to_end(block)
        else:
            self._free.append(block)

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.decref(b)

    def is_writable(self, block: int) -> bool:
        """May the (single) owner write this block in place? False for
        shared blocks and for blocks published in the prefix index —
        those must be copied first (copy-on-write)."""
        if block == NULL_BLOCK:
            return False
        return self._ref.get(block, 0) == 1 and block not in self._key

    # ------------------------------------------------------------------
    # content-hash prefix index
    # ------------------------------------------------------------------

    def _chunk_key(self, parent, chunk: Tuple[int, ...]) -> int:
        return hash((parent, chunk))

    def _lookup(self, parent, chunk: Tuple[int, ...]) -> Optional[int]:
        """Indexed block for (parent chain, exact chunk) or None; hash
        collisions are rejected by comparing the stored tokens."""
        key = self._chunk_key(parent, chunk)
        b = self._index.get(key)
        if b is None:
            return None
        if self._parent.get(b) != parent or self._tokens.get(b) != chunk:
            return None                   # hash collision -> miss
        return b

    def match_prefix(self, tokens: np.ndarray) -> PrefixMatch:
        """Longest cached prefix of `tokens` (read-only peek: takes no
        references). Full chunks match exactly through the chain index;
        the remainder may partially match the first tokens of one more
        cached block — the first divergent block, shareable with COW."""
        if not self.block_size:
            return PrefixMatch([], None, 0)
        toks = [int(t) for t in tokens]
        bs = self.block_size
        parent = _ROOT
        full: List[int] = []
        for i in range(len(toks) // bs):
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            b = self._lookup(parent, chunk)
            if b is None:
                break
            full.append(b)
            parent = self._chunk_key(parent, chunk)
        if len(full) < len(toks) // bs:   # diverged inside full chunks
            rest = tuple(toks[len(full) * bs:(len(full) + 1) * bs])
        else:
            rest = tuple(toks[len(full) * bs:])
        best, best_len = None, 0
        for cand in self._children.get(parent, ()):
            stored = self._tokens[cand]
            d = 0
            for a, c in zip(rest, stored):
                if a != c:
                    break
                d += 1
            if d > best_len:
                best, best_len = cand, d
        if best is not None and best in full:
            best, best_len = None, 0      # already counted as a full match
        return PrefixMatch(full, best, best_len)

    def share(self, match: PrefixMatch) -> None:
        """Commit a match: take one reference on every matched block
        (revives cached-free blocks). Call before the blocks can be
        evicted by a concurrent alloc."""
        for b in match.blocks():
            self.incref(b)

    def unshare(self, match: PrefixMatch) -> None:
        for b in match.blocks():
            self.decref(b)

    def touch(self, blocks: Sequence[int]) -> None:
        """LRU-touch cached-free blocks (a hit makes them hot)."""
        for b in blocks:
            if b in self._cached:
                self._cached.move_to_end(b)

    def register_prefix(self, tokens: np.ndarray,
                        blocks: Sequence[int]) -> int:
        """Publish a prompt's FULL blocks in the index (after its prefill
        completed). `blocks` are the prompt's physical blocks in table
        order. Chunks already indexed keep their canonical block; the
        sequence's duplicate stays private. Returns #newly indexed."""
        if not self.block_size:
            return 0
        toks = [int(t) for t in tokens]
        bs = self.block_size
        parent = _ROOT
        added = 0
        for i in range(len(toks) // bs):
            chunk = tuple(toks[i * bs:(i + 1) * bs])
            key = self._chunk_key(parent, chunk)
            existing = self._lookup(parent, chunk)
            if existing is None and key not in self._index:
                b = blocks[i]
                if b in self._key:        # already published under a
                    parent = key          # different chain — leave it
                    continue
                self._index[key] = b
                self._key[b] = key
                self._parent[b] = parent
                self._tokens[b] = chunk
                self._children.setdefault(parent, set()).add(b)
                added += 1
            parent = key
        return added

    def _unregister(self, block: int) -> None:
        key = self._key.pop(block, None)
        if key is None:
            return
        self._index.pop(key, None)
        parent = self._parent.pop(block, None)
        self._tokens.pop(block, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(block)
            if not kids:
                del self._children[parent]

    def reset_prefix_cache(self) -> None:
        """Drop the whole index; cached-free blocks return to the free
        list. Live shared blocks stay shared (their refcounts are
        untouched) but are no longer discoverable."""
        for b in list(self._key):
            self._unregister(b)
        while self._cached:
            b, _ = self._cached.popitem(last=False)
            self._free.append(b)
