"""Router: a multi-replica serving cluster over a cluster-wide queue.

The layer ABOVE the engine (router -> replicas -> scheduler ->
block manager -> runner). Where the engine applies the paper's tradeoff
within one machine (hold a batch, synchronize at coarse boundaries),
the router applies its distributed form across machines: replicas run
fully locally — their own queues, slots, paged pools, prefix caches —
and the only cluster-wide communication is the placement decision per
request and the completion coming back, the intermittent-communication
regime of the distributed designs in PAPERS.md.

Responsibilities:

  * cluster-wide near-FCFS queue + backpressure — requests enter the
    router's queue; `place()` moves them onto replicas only while the
    target's own queue is shallower than `max_queue` (deep enough to
    keep bucketed prefill batched, shallow enough that placement waits
    for fresh occupancy/affinity signals instead of committing the
    whole backlog blind). A request whose target is at capacity HOLDS
    its place in line, but requests within a bounded window behind it
    may pass when their own target has room (a held request waits for
    capacity, not ordering — without the jump, one full sticky home
    would idle every other replica); per-replica bucketed admission
    still reorders locally.
  * pluggable placement policies —
      'round-robin'      rotate over enabled replicas with room
      'least-loaded'     min slot+queue occupancy (ReplicaSnapshot.load)
      'prefix-affinity'  max `probe_prefix` (the BlockAllocator
                         content-hash probe): route a request to the
                         replica already holding its prompt prefix.
                         The probe only sees PREFILLED prompts, so
                         zero-match requests consult the router's own
                         cold-start pin first — the replica where a
                         request sharing this prompt's leading
                         block-size chunk was last placed (placement
                         log only; no replica state) — and fall back
                         to least-loaded when there is no pin either.
                         Without the pin, every placement issued while
                         a tenant's first prefill is still in flight
                         scatters that tenant blindly; with it, a
                         tenant is pinned from its very first
                         placement and the probe takes over once
                         blocks register.
    Ties always break to least-loaded then lowest replica id, so
    placement is deterministic for a deterministic arrival order.
  * sticky placement — once placed, a request lives and dies on its
    replica (all its paged/recurrent state is local); the one exception
    is drain/failover below.
  * drain / failover — `disable(replica_id)` stops new placement AND
    pulls the replica's queued-but-unadmitted requests back into the
    cluster queue head (original order) to requeue elsewhere; requests
    already in slots finish where they are (the replica keeps stepping
    until drained). `enable` brings it back.
  * cluster run()/stream() — the engine loop lifted one level: open-loop
    arrivals feed the cluster queue, every replica with work advances
    one step per cluster iteration, and per-replica StreamEvents merge
    into one stream. All replicas share one clock origin so latency
    telemetry is comparable.

Because every request's realization is batch-composition independent
(position-keyed sampling, argmax greedy — see serving/sampling.py),
cluster output is BIT-IDENTICAL to a single-replica run of the same
workload for every policy and replica count; only placement, timing,
and cache-hit telemetry change. serving_bench gates this.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.serving.engine import summarize
from repro.serving.observability import NULL_OBS, Observability
from repro.serving.replica import Replica
from repro.serving.scheduler import Completion, Request, StreamEvent

POLICIES = ("round-robin", "least-loaded", "prefix-affinity")

_POLICY_ALIASES = {
    "rr": "round-robin", "round-robin": "round-robin",
    "ll": "least-loaded", "least-loaded": "least-loaded",
    "prefix": "prefix-affinity", "prefix-affinity": "prefix-affinity",
}


def normalize_policy(policy: str) -> str:
    """Canonical policy name for a CLI alias ('rr', 'prefix', ...)."""
    try:
        return _POLICY_ALIASES[policy]
    except KeyError:
        raise ValueError(f"unknown router policy {policy!r} "
                         f"(available: {sorted(_POLICY_ALIASES)})")


class Router:
    """Cluster-wide request queue + placement over `replicas`.

    max_queue    per-replica cap on placed-but-unadmitted requests;
                 None derives min(num_slots, prefill_max_batch) per
                 replica (>= 1 — an idle enabled replica always
                 accepts, so placement cannot deadlock while any
                 replica is enabled).
    jump_window  how many queued requests behind a held head `place()`
                 may consider (near-FCFS; None derives 2x the cluster's
                 total queue caps).
    """

    def __init__(self, replicas: Sequence[Replica], *,
                 policy: str = "least-loaded",
                 max_queue: Optional[int] = None,
                 jump_window: Optional[int] = None,
                 obs: Observability = NULL_OBS):
        if not replicas:
            raise ValueError("router needs at least one replica")
        ids = [r.replica_id for r in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids {ids}")
        self.replicas = list(replicas)
        self.policy = normalize_policy(policy)
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self._max_queue = max_queue
        self._jump_window = jump_window
        self._queue: Deque[Request] = deque()
        self._placement: Dict[int, int] = {}   # rid -> replica_id (sticky)
        self._rr = 0                           # round-robin cursor
        # cold-start pins: leading block-size token chunk -> replica_id
        # (prefix-affinity only; see module docstring). Chunk length =
        # the smallest replica block size: the granularity at which the
        # authoritative match_prefix probe can ever match. LRU-bounded:
        # workloads without shared prefixes would otherwise grow one
        # entry per distinct prompt head for the life of the run.
        self._pins: "OrderedDict[tuple, int]" = OrderedDict()
        self._max_pins = 4096
        self._chunk_len = max(1, min(
            getattr(r.engine, "block_size", 16) for r in self.replicas))
        # probe memo: rid -> (prefill epoch, {replica_id: score}). The
        # content-hash probe can only change when some replica's prefill
        # registered new blocks, so a held request is NOT re-probed on
        # every cluster step while nothing prefilled.
        self._probe_memo: Dict[int, Tuple[int, Dict[int, int]]] = {}
        self.requeued = 0                      # drained/failed-over
        self.wall_time = 0.0
        self._obs = obs or NULL_OBS
        self._t0: Optional[float] = None       # cluster clock origin
        self._c_placed = {
            r.replica_id: self._obs.counter("router_placed_total",
                                            replica=r.replica_id)
            for r in self.replicas}
        self._c_requeued = self._obs.counter("router_requeued_total")
        # completions already collected from replicas REMOVED mid-run
        # (autoscaler scale-in) — merged back by run()
        self._done: List[Completion] = []
        # the active stream() event sink, propagated onto replicas
        # added mid-run so an elastic cluster streams seamlessly
        self._event_sink = None
        # attached by Autoscaler.attach(); ticked once per _drive sweep
        self.autoscaler = None

    def _now(self) -> float:
        """Seconds on the cluster clock (0.0 before the first run)."""
        return (time.perf_counter() - self._t0
                if self._t0 is not None else 0.0)

    # ------------------------------------------------------------------
    # queue + placement
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue on the CLUSTER queue (placement happens in place())."""
        if self._obs.enabled:
            if req.trace is None:
                req.trace = {}
            req.trace.setdefault("queued", self._now())
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r.has_work for r in self.replicas)

    def placement_of(self, rid: int) -> Optional[int]:
        """Replica id a request is (sticky-)placed on, or None."""
        return self._placement.get(rid)

    def _by_id(self, replica_id: int) -> Replica:
        for r in self.replicas:
            if r.replica_id == replica_id:
                return r
        raise KeyError(replica_id)

    def _cap(self, rep: Replica) -> int:
        if self._max_queue is not None:
            return self._max_queue
        batch = getattr(rep.engine.runner, "prefill_max_batch",
                        rep.num_slots)
        return max(1, min(rep.num_slots, batch))

    def _accepts(self, rep: Replica, snap) -> bool:
        return snap.enabled and snap.queue_depth < self._cap(rep)

    def _snaps(self) -> Dict[int, "object"]:
        return {r.replica_id: r.snapshot() for r in self.replicas}

    def _pick(self, req: Request, snaps=None) -> Optional[Replica]:
        """Target replica for `req` under the policy, or None when every
        enabled replica is at its backpressure cap. `snaps` lets a
        place() sweep reuse one set of replica snapshots across the
        whole scan (occupancy only changes when something is placed)."""
        if snaps is None:
            snaps = self._snaps()
        avail = [r for r in self.replicas
                 if self._accepts(r, snaps[r.replica_id])]
        if not avail:
            return None

        def least_loaded(cands):
            return min(cands, key=lambda r: (snaps[r.replica_id].load,
                                             r.replica_id))

        if self.policy == "round-robin":
            for _ in range(len(self.replicas)):
                r = self.replicas[self._rr % len(self.replicas)]
                self._rr += 1
                if r in avail:
                    return r
            return None                   # unreachable: avail is nonempty
        if self.policy == "least-loaded":
            return least_loaded(avail)
        # prefix-affinity: the replica whose BlockAllocator already holds
        # the longest prefix of this prompt; no holder yet -> follow the
        # cold-start pin (where this leading chunk was last placed);
        # no pin either -> least-loaded, and pin the choice. Affinity is
        # STICKY under backpressure: when the home replica (holder or
        # pin) is enabled but momentarily at its queue cap, the request
        # WAITS at the cluster-queue head rather than overflowing onto a
        # replica that would recompute the whole prefix — the home's
        # queue drains every admission round, so the hold is bounded.
        chunk = self._chunk(req.prompt)
        enabled = [r for r in self.replicas if snaps[r.replica_id].enabled]
        by_id = self._probe(req)
        scores = [(by_id[r.replica_id], r) for r in enabled]
        best = max(s for s, _ in scores)
        if best > 0:
            homes = [r for s, r in scores if s == best]
            in_avail = [r for r in homes if r in avail]
            if not in_avail:
                return None               # hold for the holder(s)
            pick = least_loaded(in_avail)
        else:
            pinned = self._pins.get(chunk) if chunk else None
            home = next((r for r in enabled if r.replica_id == pinned),
                        None)
            if home is not None:
                if home not in avail:
                    return None           # hold for the pinned home
                pick = home
            else:
                pick = least_loaded(avail)
        if chunk:
            self._pins[chunk] = pick.replica_id
            self._pins.move_to_end(chunk)
            while len(self._pins) > self._max_pins:
                self._pins.popitem(last=False)        # LRU
        return pick

    def _chunk(self, prompt) -> Optional[tuple]:
        """Leading block-size chunk of a prompt (the pin key), or None
        when the prompt has no fully-cacheable leading chunk."""
        if len(prompt) <= self._chunk_len:
            return None
        return tuple(int(t) for t in prompt[:self._chunk_len])

    def _probe(self, req: Request) -> Dict[int, int]:
        """Per-replica affinity scores for `req`, memoized on the
        cluster prefill epoch (the probe can only change when a prefill
        registers new blocks) so held requests cost nothing to rescan."""
        epoch = sum(getattr(r.engine.runner, "prefill_dispatches", 0)
                    for r in self.replicas)
        hit = self._probe_memo.get(req.rid)
        if hit is not None and hit[0] == epoch:
            return hit[1]
        scores = {r.replica_id: r.probe_prefix(req.prompt)
                  for r in self.replicas}
        self._probe_memo[req.rid] = (epoch, scores)
        return scores

    def place(self) -> int:
        """Move requests from the cluster queue onto replicas
        (near-FCFS, policy-picked, backpressured). A held request keeps
        its place in line; requests within `jump_window` behind it may
        pass when their own target has room. Returns #placed."""
        window = (self._jump_window if self._jump_window is not None
                  else 2 * sum(self._cap(r) for r in self.replicas))
        placed = 0
        snaps = self._snaps()
        while self._queue:
            target = None
            for i, req in enumerate(self._queue):
                if i > window:
                    break
                rep = self._pick(req, snaps)
                if rep is not None:
                    target = (i, req, rep)
                    break
            if target is None:
                break                     # everything in-window is held
            i, req, rep = target
            del self._queue[i]
            if self._obs.enabled:
                if req.trace is None:
                    req.trace = {}
                req.trace["routed"] = self._now()
            self._c_placed[rep.replica_id].inc()
            rep.submit(req)
            self._placement[req.rid] = rep.replica_id
            self._probe_memo.pop(req.rid, None)
            # only the chosen replica's occupancy changed this sweep
            snaps[rep.replica_id] = rep.snapshot()
            placed += 1
        return placed

    # ------------------------------------------------------------------
    # drain / failover
    # ------------------------------------------------------------------

    def disable(self, replica_id: int) -> List[Request]:
        """Drain a replica: stop placing onto it and pull its queued-but-
        unadmitted requests back to the FRONT of the cluster queue (in
        their original order) so `place()` requeues them elsewhere.
        Requests already admitted to slots keep running to completion —
        the replica still steps until it empties. Returns the requeued
        requests."""
        rep = self._by_id(replica_id)
        rep.enabled = False
        orphans = rep.take_queued()
        for r in reversed(orphans):
            self._queue.appendleft(r)
            self._placement.pop(r.rid, None)
        self.requeued += len(orphans)
        self._c_requeued.inc(len(orphans))
        return orphans

    def enable(self, replica_id: int) -> None:
        self._by_id(replica_id).enabled = True

    # ------------------------------------------------------------------
    # elastic membership (the autoscaler's levers)
    # ------------------------------------------------------------------

    def add_replica(self, rep: Replica) -> None:
        """Join a replica to the cluster, enabled, mid-run or between
        runs. Mid-run joiners adopt the cluster clock WITHOUT a
        begin_run (which would wipe the shared metrics registry) and
        inherit the active stream() event sink."""
        if any(r.replica_id == rep.replica_id for r in self.replicas):
            raise ValueError(f"replica {rep.replica_id} already joined")
        self.replicas.append(rep)
        rep.enabled = True
        if rep.replica_id not in self._c_placed:
            self._c_placed[rep.replica_id] = self._obs.counter(
                "router_placed_total", replica=rep.replica_id)
        self._chunk_len = max(1, min(
            getattr(r.engine, "block_size", 16) for r in self.replicas))
        if self._t0 is not None:
            rep.align_clock(self._t0)
        if self._event_sink is not None:
            rep.scheduler.on_event = self._event_sink

    def remove_replica(self, replica_id: int) -> Replica:
        """Detach a DRAINED replica (scale-in): its completions are
        held for run() and its engine stack returns to the caller
        (the autoscaler's standby pool keeps it jit-warm). Refuses to
        remove a replica that still has work or the last one."""
        rep = self._by_id(replica_id)
        if rep.has_work:
            raise RuntimeError(
                f"replica {replica_id} still has work — disable() it "
                f"and let it drain before removing")
        if len(self.replicas) == 1:
            raise RuntimeError("cannot remove the last replica")
        self.replicas.remove(rep)
        self._done.extend(rep.take_completions())
        if self._event_sink is not None:
            rep.scheduler.on_event = None
        self._chunk_len = max(1, min(
            getattr(r.engine, "block_size", 16) for r in self.replicas))
        return rep

    # ------------------------------------------------------------------
    # cluster run / stream
    # ------------------------------------------------------------------

    def _drive(self, requests: Sequence[Request]) -> Iterator[None]:
        """The cluster loop: open-loop arrivals into the cluster queue,
        place, then one engine step per replica-with-work per iteration
        (round-robin stepping keeps replicas advancing together without
        any cross-replica synchronization). Yields after every sweep so
        `stream` can drain events."""
        pending = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        t0 = time.perf_counter()
        self._t0 = t0
        # per-run state resets; the cluster queue is NOT cleared —
        # requests already submit()ed directly keep their place and
        # drain with this run (matching ServingEngine.run semantics)
        self._placement.clear()
        self._pins.clear()
        self._probe_memo.clear()
        self._rr = 0
        self.requeued = 0
        self._done = []
        if self.autoscaler is not None:
            # retire autoscaled replicas to standby FIRST so only the
            # base set gets begin_run (and one shared registry reset)
            self.autoscaler.begin_run(t0)
        for rep in self.replicas:
            rep.begin_run(t0)
        while idx < len(pending) or self.has_work:
            now = time.perf_counter() - t0
            while idx < len(pending) and pending[idx].arrival <= now:
                self.submit(pending[idx])
                idx += 1
            self.place()
            if self.autoscaler is not None:
                self.autoscaler.tick(now)
            stepped = False
            for rep in self.replicas:
                if rep.has_work:
                    rep.step()
                    stepped = True
            if stepped:
                yield
                continue
            if self._queue and not any(r.enabled for r in self.replicas):
                raise RuntimeError(
                    f"{len(self._queue)} requests queued but every "
                    f"replica is disabled — enable() one or drain the "
                    f"queue")
            if idx < len(pending):        # idle until the next arrival
                time.sleep(min(pending[idx].arrival - now, 0.05))
        self.wall_time = time.perf_counter() - t0

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Drain `requests` across the cluster and return the merged
        completions (blocking). Outputs are bit-identical to a
        single-replica run of the same workload — only placement and
        timing differ."""
        for _ in self._drive(requests):
            pass
        done: List[Completion] = list(self._done)   # scaled-in replicas
        for rep in self.replicas:
            done.extend(rep.take_completions())
        done.sort(key=lambda c: c.t_done)
        return done

    def stream(self, requests: Sequence[Request]) -> Iterator[StreamEvent]:
        """Drain `requests`, merging every replica's StreamEvents into
        one stream (token events as each replica's steps land them,
        then a done event per request). Token-for-token equivalent to
        `run()`. Like ServingEngine.stream, the generator must be
        consumed to exhaustion."""
        buf: List[StreamEvent] = []
        prev = {rep.replica_id: rep.scheduler.on_event
                for rep in self.replicas}
        self._event_sink = buf.append        # added replicas inherit it
        for rep in self.replicas:
            rep.scheduler.on_event = self._event_sink
        try:
            for _ in self._drive(requests):
                while buf:
                    yield buf.pop(0)
            while buf:
                yield buf.pop(0)
            self._done = []
            for rep in self.replicas:
                rep.take_completions()
        finally:
            self._event_sink = None
            for rep in self.replicas:
                rep.scheduler.on_event = prev.get(rep.replica_id)


def summarize_cluster(completions: Sequence[Completion], wall: float,
                      router: Router) -> Dict:
    """Cluster telemetry: the engine-level latency/throughput stats over
    the merged completions plus a `cluster` block — placement counts,
    per-replica occupancy/prefill/cache numbers, and the cluster-wide
    cached-token total the policy benchmarks compare."""
    stats = summarize(completions, wall)
    per = []
    for rep in router.replicas:
        sched, runner = rep.scheduler, rep.engine.runner
        snap = rep.snapshot()
        per.append({
            "replica": rep.replica_id,
            "enabled": rep.enabled,
            "placed": rep.placed,
            "steps": rep.engine.steps,
            "prefill_dispatches": runner.prefill_dispatches,
            "prompt_tokens": sched.prompt_tokens,
            "cached_prompt_tokens": sched.cached_prompt_tokens,
            "prefix_hit_requests": sched.prefix_hit_requests,
            "preemptions": sched.preemptions,
            "resumes": sched.resumes,
            "shed": sched.shed_requests,
            "deferrals": sched.deferrals,
            "warm_blocks": snap.cached_blocks,
            "indexed_blocks": snap.indexed_blocks,
        })
    stats["cluster"] = {
        "policy": router.policy,
        "replicas": len(router.replicas),
        "requeued": router.requeued,
        "placed": [p["placed"] for p in per],
        "prompt_tokens": sum(p["prompt_tokens"] for p in per),
        "cached_prompt_tokens": sum(p["cached_prompt_tokens"]
                                    for p in per),
        "preemptions": sum(p["preemptions"] for p in per),
        "resumes": sum(p["resumes"] for p in per),
        "shed_requests": sum(p["shed"] for p in per),
        "deferrals": sum(p["deferrals"] for p in per),
        "per_replica": per,
    }
    if router.autoscaler is not None:
        stats["cluster"]["autoscaler"] = router.autoscaler.summary()
    return stats
