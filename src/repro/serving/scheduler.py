"""Scheduler: queue, admission policy, request lifecycle, unified stop
handling, eviction, and the propose/accept/rollback half of
speculative decoding.

The top layer of the serving engine (scheduler -> block manager ->
runner). It owns every request-level decision and no device state:

  * per-request SamplingParams — `submit` resolves each request's
    sampling config (request sampling > engine default, legacy
    max_new_tokens / eos_id folded in), tracks it per slot, and hands
    it to the runner as data (the runner mirrors it to the device as
    (num_slots,) arrays, so batches freely mix greedy, sampled, and
    speculative-sampled lanes in ONE dispatch).
  * unified stop handling — eos and multi-token stop sequences are one
    code path: a resolved list of stop token sequences per slot,
    scanned over the generated output after every emission (matching
    never spans into the prompt). A stop landing mid-speculative-chain
    truncates the accepted run at the stop and rolls the rest back —
    recurrent state commits at the truncated length and the chain's
    unused block claims are freed.
  * priority queue with bucketed batch formation — admission orders
    the queue by (effective priority desc, submit order), where
    effective priority is the request's static class plus an aging
    boost (+1 class per `priority_aging_s` seconds waited, so a
    low-priority request overtakes class p+k after at most
    k * priority_aging_s seconds — the starvation bound; equal-class
    traffic stays FCFS). The head request's prefix-cache match picks
    its suffix-length bucket, then further queued requests in the SAME
    bucket join (bounded queue-jumping: other buckets keep their
    place) until slots, blocks, or the prefill batch width run out.
    The whole group is admitted in ONE `runner.prefill` dispatch.
  * preemption with bit-identical resume — when a waiting request's
    static class outranks a running lane's and admission is blocked
    (no free lane, or the pool can't cover the reservation),
    `preempt()` evicts the weakest running lane: every FULL block of
    its prompt+generated KV is published in the prefix index first, so
    the teardown decrefs park them in the cached-free pool instead of
    losing them, and a resume request (prompt' = the tokens whose KV
    was already computed) re-enters the queue at the original class
    and submit order. Resume is a plain re-admission: the full blocks
    come back as prefix-cache hits, the partial tail recomputes, and
    the resumed prefill's sampled token — keyed by position exactly
    like the decode step it replays — is asserted equal to the token
    captured at preemption, then suppressed (never re-emitted). A
    preempted-then-resumed request is bit-identical to an
    uninterrupted run.
  * incremental block allocation under a conservative budget —
    admission allocates only the prompt's blocks and RESERVES (but does
    not bind) the ceil((prompt + max_new) / block_size) remainder as a
    per-slot budget; generation claims physical blocks lazily as
    positions cross block boundaries and a draft chain claims the
    blocks its tokens would write up front. The global reserved-budget
    counter keeps admission honest (a live sequence can always claim
    its full budget — no deadlock), while unclaimed blocks stay in the
    allocator's pools, so cached prefix blocks survive longer under
    pressure than with bind-everything-at-admission.
  * prefix sharing + copy-on-write — matched full blocks are shared by
    refcount; a partially-matched (first divergent) block is shared and
    then copied before its first write: eagerly at admission when the
    prompt itself diverges mid-block, lazily at the first decode step
    when the whole prompt was cached and only generation writes into it.
  * speculative decoding — each slot owns an n-gram draft proposer
    (serving/draft.py) over its prompt + generated history.
    `prepare_verify` assembles per-lane draft chains [pending, d1..dk],
    claims the blocks the chain would write, and pads to the runner's
    verify bucket; `consume_verify` takes the runner's emitted tokens
    and accept counts (greedy compare or Leviathan accept/reject — see
    serving/sampling.py), commits recurrent state at the accepted (and
    stop-truncated) length through the runner, and frees exactly the
    blocks a rejected suffix had claimed (the allocator returns to its
    pre-draft state — property-tested).
  * lifecycle + eviction + streaming — finished sequences
    (max_new_tokens or a stop hit) are evicted: their table row is
    nulled, their lane freed, every block reference dropped, and their
    unclaimed budget released. Every emission and completion fires the
    optional `on_event` callback (the engine's `stream()` source).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.block_manager import (NULL_BLOCK, BlockAllocator,
                                         PrefixMatch)
from repro.serving.draft import make_proposer
from repro.serving.observability import NULL_OBS, Observability
from repro.serving.runner import ModelRunner, PrefillRow
from repro.serving.sampling import SamplingParams, resolve
from repro.serving.slo import SLO_TID, SLOTracker


@dataclasses.dataclass
class Request:
    """One serving request. `sampling` carries the decoding config;
    `max_new_tokens` / `eos_id` are the legacy per-request fields and
    stay honored (merged into the resolved SamplingParams at submit —
    the resolved config is written back to `sampling`, and
    `max_new_tokens` is back-filled, so both views agree downstream)."""
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: Optional[int] = None
    arrival: float = 0.0          # seconds on the engine clock (open loop)
    eos_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    priority: int = 0             # scheduling class: higher admits first
    #                               and may preempt strictly lower classes
    trace: Optional[Dict[str, float]] = None
    # lifecycle timestamps on the shared run clock, stamped only while
    # observability tracing is on (router stamps 'queued'/'routed', the
    # scheduler stamps 'queued' for un-routed requests); None by default
    # so the recorder-off path carries no per-request cost


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    arrival: float
    t_admit: float
    t_first_token: float
    t_done: float
    cached_tokens: int = 0        # prompt tokens served from the prefix cache
    finish_reason: str = "length"  # 'length' | 'stop' | 'shed' (an SLO
    #                               shed: never admitted, tokens empty)
    logprobs: Optional[np.ndarray] = None   # (n_generated,) float32 if
    #                               SamplingParams.logprobs was requested
    top_ids: Optional[np.ndarray] = None       # (n_generated, k) int32 and
    top_logprobs: Optional[np.ndarray] = None  # (n_generated, k) float32:
    #                               the k alternative tokens per emitted
    #                               position (SamplingParams.logprobs=k)


@dataclasses.dataclass
class StreamEvent:
    """One increment of a streaming completion: `tokens` newly emitted
    for `rid` (several at once under speculation), then a final event
    with done=True carrying the Completion (and no new tokens)."""
    rid: int
    tokens: List[int]
    logprobs: Optional[List[float]] = None
    top_ids: Optional[List[List[int]]] = None       # per new token: the k
    top_logprobs: Optional[List[List[float]]] = None  # alternatives
    done: bool = False
    completion: Optional[Completion] = None


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """Structured occupancy snapshot — the telemetry a replica router
    places on (queue + slot load, allocator block supply) without
    poking scheduler internals."""
    queue_depth: int              # submitted, not yet admitted
    active_slots: int             # lanes currently decoding
    free_slots: int
    free_blocks: int              # allocatable (free + evictable cached)
    cached_blocks: int            # cached-free blocks holding warm prefixes
    indexed_blocks: int           # blocks published in the prefix index
    reserved_blocks: int          # reserved-but-unbound generation budget
    spilled_blocks: int = 0       # host-tier block payloads (spill tier)
    preempted: int = 0            # evicted lanes awaiting resume (their
    #                               resume requests also count in
    #                               queue_depth — load sees them once)

    @property
    def load(self) -> int:
        """Slot + queue occupancy — the least-loaded routing signal."""
        return self.queue_depth + self.active_slots


@dataclasses.dataclass
class _Slot:
    req: Request
    sp: SamplingParams            # resolved sampling config
    stops: List[List[int]]        # resolved stop token sequences
    table_row: np.ndarray         # (max_blocks,) int32, NULL padded
    pos: int                      # position of the next token to feed
    pending: int                  # token to feed at `pos`
    out: List[int]
    hist: List[int]               # prompt + generated (proposer input)
    t_admit: float
    t_first: float
    cached: int                   # prefix-cache hit tokens at admission
    n_blocks: int                 # bound physical blocks (row prefix)
    prompt_blocks: int            # blocks covering the prompt (floor)
    budget: int                   # reserved-but-unbound blocks remaining
    cow_block: Optional[int]      # reserved private copy for the shared
    cow_index: int = -1           # first-divergent block (lazy COW)
    lps: Optional[List[float]] = None   # chosen-token logprobs if asked
    alts: Optional[List[Tuple[List[int], List[float]]]] = None
    #                             # per-position top-k (ids, logprobs)
    stopped: bool = False         # a stop sequence completed
    # chunked prefill (prompts longer than the largest prefill bucket):
    # next uncomputed prompt position while the admission is still being
    # prefilled chunk-by-chunk, -1 once prefill is complete. A slot with
    # prefill_pos >= 0 holds its blocks/budget but sits out decode and
    # verify dispatches until its final chunk lands the first token.
    prefill_pos: int = -1
    prefill_chunks: int = 0       # chunks dispatched so far
    prefill_chunks_total: int = 0


@dataclasses.dataclass
class _Plan:
    """A reserved admission: prompt blocks held, budget reserved, table
    row built, ready for one row of a batched prefill dispatch."""
    req: Request
    table_row: np.ndarray
    slot: int
    cached: int
    n_blocks: int
    budget: int
    cow_block: Optional[int]
    cow_index: int
    t_admit: float

    @property
    def suffix_len(self) -> int:
        return len(self.req.prompt) - min(self.cached,
                                          len(self.req.prompt) - 1)


@dataclasses.dataclass
class _ResumeState:
    """Everything a preempted lane needs to continue exactly where it
    stopped, keyed by rid while its resume request waits in the queue.
    The KV itself is NOT here — it sits in the cached-free pool (full
    blocks, published at preemption) until the resume admission revives
    it as a prefix match."""
    req: Request                  # the ORIGINAL request object
    sp: SamplingParams            # resolved sampling (original max_new)
    stops: List[List[int]]
    out: List[int]
    hist: List[int]
    pos: int                      # next position to feed at resume
    pending: int                  # token to feed there (already emitted)
    t_admit: float                # original admission time (TTFT keeps)
    t_first: float
    cached: int                   # original admission cache-hit tokens
    lps: Optional[List[float]]
    alts: Optional[List[Tuple[List[int], List[float]]]]


class Scheduler:
    """Request lifecycle over a BlockAllocator and a ModelRunner."""

    def __init__(self, allocator: BlockAllocator, runner: ModelRunner, *,
                 num_slots: int, block_size: int, max_blocks_per_seq: int,
                 max_seq_len: int, prefix_cache: bool,
                 now_fn: Callable[[], float], speculate: int = 0,
                 draft: str = "ngram", ngram: int = 3,
                 default_sampling: Optional[SamplingParams] = None,
                 priority_aging_s: float = 2.0,
                 slo_tracker: Optional[SLOTracker] = None,
                 slo_shed: bool = False,
                 obs: Observability = NULL_OBS):
        self.allocator = allocator
        self.runner = runner
        self._obs = obs or NULL_OBS
        # SLO layer (optional): the tracker receives TTFT / e2e latency
        # / TPOT observations and prices queued requests' expected wait;
        # slo_shed additionally enables deadline-aware admission (EDF
        # slack ordering + shed-on-hopeless). Shedding is OPT-IN: with
        # it off, deadlines are informational and admission order is
        # untouched, so every bit-identity gate is unaffected.
        self.slo = slo_tracker
        self.slo_shed = bool(slo_shed)
        # instruments resolved once (no-ops when obs is off)
        self._c_submitted = self._obs.counter("scheduler_submitted_total")
        self._c_admitted = self._obs.counter("scheduler_admitted_total")
        self._c_finished = {
            r: self._obs.counter("scheduler_finished_total", reason=r)
            for r in ("length", "stop", "shed")}
        self._c_shed = self._obs.counter("slo_shed_total")
        self._c_deferred = self._obs.counter("slo_deferred_total")
        self._c_ttft_breach = self._obs.counter("slo_ttft_breach_total")
        self._c_lat_breach = self._obs.counter("slo_latency_breach_total")
        self._c_tokens = self._obs.counter("tokens_emitted_total")
        self._c_prompt = self._obs.counter("prompt_tokens_total")
        self._c_cached = self._obs.counter("cached_prompt_tokens_total")
        self._c_proposed = self._obs.counter("spec_proposed_total")
        self._c_accepted = self._obs.counter("spec_accepted_total")
        self._c_preempted = self._obs.counter("scheduler_preempted_total")
        self._c_resumed = self._obs.counter("scheduler_resumed_total")
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_seq_len = max_seq_len
        self.prefix_cache = prefix_cache
        self._now = now_fn
        self.speculate = max(0, speculate)
        self.priority_aging_s = float(priority_aging_s)
        self.default_sampling = default_sampling or SamplingParams()
        # one proposer per lane: drafting is per-sequence state-free
        # today (n-gram lookup), but the ownership point is the seam a
        # stateful draft-model proposer will need
        self._proposers = [make_proposer(draft, ngram=ngram)
                           for _ in range(num_slots)] if speculate else []
        # per-slot acceptance telemetry (the signal ROADMAP's adaptive
        # speculation length will steer by — recorded, not acted on):
        # an accept-length histogram per slot plus a rolling acceptance
        # rate over the last `_accept_window` verify dispatches
        if self.speculate and self._obs.enabled:
            bounds = list(range(self.speculate + 1))
            self._h_accept = self._obs.histogram("verify_accept_len_hist",
                                                 bounds)
            self._h_accept_slot = [
                self._obs.histogram("verify_accept_len_hist", bounds,
                                    slot=i) for i in range(num_slots)]
            self._g_accept_rate = [
                self._obs.gauge("spec_accept_rate", slot=i)
                for i in range(num_slots)]
            self._accept_window = [deque(maxlen=32)
                                   for _ in range(num_slots)]
        else:
            self._accept_window = []
        self._last_proposed: Dict[int, int] = {}
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * num_slots
        self._reserved_budget = 0     # sum of live slots' budgets
        self._chunk_rr = 0            # round-robin over chunked prefills
        self._submit_seq = 0          # FCFS tiebreak within a priority
        # rid -> _ResumeState for preempted lanes whose resume request
        # is waiting in the queue (take_queued never migrates these:
        # their cached KV lives on THIS replica's allocator)
        self._resume_state: Dict[int, _ResumeState] = {}
        self.completions: List[Completion] = []
        self.on_event: Optional[Callable[[StreamEvent], None]] = None
        self.reset_stats()

    def reset_stats(self) -> None:
        self.prompt_tokens = 0
        self.cached_prompt_tokens = 0
        self.prefix_hit_requests = 0
        self.proposed_tokens = 0      # draft tokens sent to verify
        self.accepted_tokens = 0      # draft tokens accepted
        self.greedy_requests = 0      # submitted with temperature == 0
        self.sampled_requests = 0     # submitted with temperature > 0
        self.preemptions = 0          # lanes evicted by preempt()
        self.resumes = 0              # preempted lanes re-admitted
        self.shed_requests = 0        # SLO-shed before admission
        self.deferrals = 0            # requests EDF-deferred at least once

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Validate, resolve the request's SamplingParams (request >
        engine default, legacy max_new_tokens/eos_id merged in), and
        enqueue. The resolved config is written back onto the request
        so every later stage reads one authoritative view."""
        sp = resolve(req.sampling, self.default_sampling,
                     max_new_tokens=req.max_new_tokens, eos_id=req.eos_id,
                     rid=req.rid)
        req.sampling = sp
        req.max_new_tokens = sp.max_new_tokens
        if len(req.prompt) + sp.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + sp.max_new_tokens} exceeds "
                f"max_seq_len {self.max_seq_len}")
        top = self.runner.prefill_buckets[-1]
        if not self.runner.prefill_chunk and len(req.prompt) > top:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"exceeds the largest prefill bucket {top} and chunked "
                f"admission is disabled — enable it (prefill_chunk > 0, "
                f"serve.py --prefill-chunk) or widen --prefill-buckets")
        cap = getattr(self.runner, "max_logprobs", None)
        if cap is not None and sp.logprobs > cap:
            raise ValueError(
                f"request {req.rid}: logprobs={sp.logprobs} exceeds the "
                f"runner's max_logprobs {cap} (the compiled top-k width)")
        if sp.greedy:
            self.greedy_requests += 1
        else:
            self.sampled_requests += 1
        # admission-order stamps (object attributes, not dataclass
        # fields: a Request resubmitted after drain/failover re-stamps)
        req._seq = self._submit_seq
        req._t_submit = self._now()
        self._submit_seq += 1
        if self._obs.enabled:
            self._c_submitted.inc()
            if req.trace is None:
                req.trace = {}
            req.trace.setdefault("queued", self._now())
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def stats(self) -> SchedulerStats:
        """Occupancy snapshot (see SchedulerStats): what a router needs
        to place load, and what serving telemetry reports."""
        active = sum(1 for s in self._slots if s is not None)
        return SchedulerStats(
            queue_depth=len(self._queue),
            active_slots=active,
            free_slots=self.num_slots - active,
            free_blocks=self.allocator.num_free,
            cached_blocks=self.allocator.num_cached,
            indexed_blocks=self.allocator.num_indexed,
            reserved_blocks=self._reserved_budget,
            spilled_blocks=getattr(self.allocator, "num_spilled", 0),
            preempted=len(self._resume_state))

    def slot_acceptance_rates(self) -> List[Optional[float]]:
        """Rolling per-slot draft acceptance rate (accepted/proposed over
        the last 32 verify dispatches), None for slots with no verify
        history yet. The signal an adaptive speculation-length policy
        would consume; requires observability to be on."""
        out: List[Optional[float]] = [None] * self.num_slots
        for i, win in enumerate(self._accept_window):
            prop = sum(p for p, _ in win)
            if prop > 0:
                out[i] = sum(a for _, a in win) / prop
        return out

    def take_queued(self) -> List[Request]:
        """Pull every queued-but-unadmitted request out of the queue, in
        order (drain/failover: the router requeues them on another
        replica). Admitted requests keep their slots and run to
        completion. The submit-time greedy/sampled counters are rolled
        back so this scheduler's stats count only work it kept. Resume
        requests for preempted lanes STAY: their cached KV and resume
        state live on this replica's allocator, so migrating them would
        turn a warm resume into a cold (and state-less) restart."""
        out = []
        kept: Deque[Request] = deque()
        for r in self._queue:
            if r.rid in self._resume_state:
                kept.append(r)
                continue
            out.append(r)
            if r.sampling.greedy:
                self.greedy_requests -= 1
            else:
                self.sampled_requests -= 1
        self._queue = kept
        return out

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _match(self, req: Request) -> PrefixMatch:
        if not self.prefix_cache:
            return PrefixMatch([], None, 0)
        return self.allocator.match_prefix(req.prompt)

    def _reserve(self, req: Request, slot: int,
                 match: PrefixMatch) -> Optional[_Plan]:
        """Share the matched prefix blocks, allocate the prompt's
        remaining blocks, reserve the generation budget, build the
        table row. Returns None (nothing held) if the pool is short."""
        P = len(req.prompt)
        bs = self.block_size
        total = -(-(P + req.sampling.max_new_tokens) // bs)
        n_prompt = -(-P // bs)
        budget = total - n_prompt
        f = len(match.full_blocks)
        # the admission gate is still conservative (the FULL extent must
        # be coverable) so an admitted request can never deadlock — but
        # only the prompt blocks are bound now; the rest stays a budget.
        # Matched blocks parked in the cached-free pool count as
        # allocatable supply in num_free, yet share() is about to revive
        # them — charge for those too, or the reserved-budget invariant
        # (num_free >= _reserved_budget, what makes _claim_blocks
        # infallible) breaks under a tight pool.
        revived = sum(1 for b in match.blocks()
                      if self.allocator.refcount(b) == 0)
        if (total - f + revived
                > self.allocator.num_free - self._reserved_budget):
            return None
        self.allocator.share(match)       # revive + hold before alloc
        fresh = self.allocator.alloc(n_prompt - f)
        if fresh is None:                 # unreachable given the gate
            self.allocator.unshare(match)
            return None
        row = np.full(self.max_blocks_per_seq, NULL_BLOCK, np.int32)
        row[:f] = match.full_blocks
        cached = f * bs + match.partial_len
        cow_block, cow_index = None, -1
        rest = fresh
        if match.partial_block is not None:
            if match.partial_len == P - f * bs:
                # whole prompt cached up to this block: keep sharing it;
                # generation's first write will trigger the lazy copy
                row[f] = match.partial_block
                cow_block, cow_index = fresh[0], f
            else:
                # prompt diverges mid-block: copy now, prefill writes it
                self.runner.copy_block(match.partial_block, fresh[0])
                self.allocator.decref(match.partial_block)
                row[f] = fresh[0]
            rest = fresh[1:]
            row[f + 1:f + 1 + len(rest)] = rest
        else:
            row[f:f + len(fresh)] = fresh
        self._reserved_budget += budget
        self.prompt_tokens += P
        self.cached_prompt_tokens += min(cached, P - 1)
        self._c_prompt.inc(P)
        self._c_cached.inc(min(cached, P - 1))
        if cached > 0:
            self.prefix_hit_requests += 1
            self.allocator.touch(match.full_blocks)
        return _Plan(req=req, table_row=row, slot=slot, cached=cached,
                     n_blocks=n_prompt, budget=budget, cow_block=cow_block,
                     cow_index=cow_index, t_admit=self._now())

    def _defer_for_group_prefix(self, req: Request, match: PrefixMatch,
                                plans: List[_Plan]) -> bool:
        """True when `req`'s prompt shares MORE full prefix blocks with
        a groupmate already in `plans` than the index currently matches:
        admitting it in this same dispatch would recompute a prefix that
        registers the moment the group's prefill lands (rows of one
        batched dispatch cannot read blocks their groupmates are about
        to write). Deferring it to the NEXT group — formed later in this
        very admit() call, after `_dispatch` registered the blocks —
        turns those tokens into cache hits instead."""
        if not self.prefix_cache:
            return False
        bs = self.block_size
        matched = match.tokens(bs)
        a = req.prompt
        for p in plans:
            b = p.req.prompt
            m = min(len(a), len(b))
            eq = np.asarray(a[:m]) == np.asarray(b[:m])
            shared = int(eq.argmin()) if not eq.all() else m
            if (shared // bs) * bs > matched:
                return True
        return False

    def _eff_priority(self, req: Request, now: float) -> float:
        """Effective ADMISSION priority: the static class plus an aging
        boost of one class per `priority_aging_s` seconds waited, so a
        class-p request behind class p+k traffic overtakes it after at
        most k * priority_aging_s seconds (the starvation bound).
        Equal-class traffic stays FCFS (older = bigger boost). Aging
        raises admission rank only — never eviction rights (see
        `_preempt_below`). priority_aging_s <= 0 disables aging."""
        if self.priority_aging_s <= 0:
            return float(req.priority)
        waited = max(now - getattr(req, "_t_submit", now), 0.0)
        return req.priority + waited / self.priority_aging_s

    def _admission_order(self) -> List[Request]:
        now = self._now()

        def base_key(r):
            return (-self._eff_priority(r, now), getattr(r, "_seq", 0))

        if not self.slo_shed:
            return sorted(self._queue, key=base_key)

        # deadline-aware ordering (slo_shed only): within an (aged)
        # priority class, earliest-deadline-first by slack — a request
        # whose deadline is tight admits ahead of comfortable or
        # deadline-free groupmates (those are the DEFERRED ones; class
        # boundaries and the aging starvation bound still hold at
        # integer-class granularity, and deadline-free traffic keeps
        # FCFS among itself)
        def slo_key(r):
            dl = self._abs_deadline(r)
            slack = dl - now if dl is not None else float("inf")
            return (-int(self._eff_priority(r, now)), slack,
                    getattr(r, "_seq", 0))

        order = sorted(self._queue, key=slo_key)
        baseline = sorted(self._queue, key=base_key)
        pos = {id(r): i for i, r in enumerate(baseline)}
        for i, r in enumerate(order):
            # count each request's FIRST slip behind its deadline-blind
            # position — the defer-below-deadline decision, visible as
            # a counter + trace instant
            if i > pos[id(r)] and not getattr(r, "_deferred", False):
                r._deferred = True
                self.deferrals += 1
                self._c_deferred.inc()
                if self._obs.enabled:
                    self._obs.instant(SLO_TID, "defer", "slo", now,
                                      rid=r.rid)
        return order

    # ------------------------------------------------------------------
    # SLO admission: deadlines, shed-on-hopeless, breach observation
    # ------------------------------------------------------------------

    @staticmethod
    def _abs_deadline(req: Request) -> Optional[float]:
        """Absolute first-token deadline on the run clock, or None.
        `deadline_ms` is relative to the request's ARRIVAL (queue wait
        counts against the budget, as a user would account it)."""
        sp = req.sampling
        if sp is None or sp.deadline_ms is None:
            return None
        return req.arrival + sp.deadline_ms / 1e3

    def _shed_hopeless(self) -> None:
        """Shed queued (never-admitted) requests that cannot make their
        deadline: already past it, or past it once the tracker's live
        median TTFT is added to `now`. A shed is a terminal Completion
        (finish_reason "shed", no tokens) plus a counter and a trace
        instant — the caller gets a definitive answer now instead of a
        uselessly late one, and the freed work protects everyone else's
        objective. Resume requests are never shed: their lane already
        produced (and streamed) tokens."""
        if not self.slo_shed or not self._queue:
            return
        now = self._now()
        est = self.slo.ttft_quantile(0.5) if self.slo is not None else None
        kept: Deque[Request] = deque()
        for r in self._queue:
            dl = None if r.rid in self._resume_state \
                else self._abs_deadline(r)
            if dl is not None and (now > dl
                                   or (est is not None
                                       and now + est > dl)):
                self._shed(r, now, dl, est)
            else:
                kept.append(r)
        self._queue = kept

    def _shed(self, req: Request, now: float, deadline: float,
              est: Optional[float]) -> None:
        comp = Completion(
            rid=req.rid, prompt_len=len(req.prompt),
            tokens=np.zeros(0, np.int32), arrival=req.arrival,
            t_admit=now, t_first_token=now, t_done=now,
            finish_reason="shed")
        self.completions.append(comp)
        self.shed_requests += 1
        self._c_shed.inc()
        self._c_finished["shed"].inc()
        if self._obs.enabled:
            self._obs.instant(
                SLO_TID, "shed", "slo", now, rid=req.rid,
                waited_ms=round((now - req.arrival) * 1e3, 3),
                deadline_ms=round((deadline - req.arrival) * 1e3, 3),
                est_ttft_ms=(round(est * 1e3, 3)
                             if est is not None else None))
        if self.on_event is not None:
            self.on_event(StreamEvent(rid=req.rid, tokens=[], done=True,
                                      completion=comp))

    def _observe_ttft(self, s: "_Slot") -> None:
        """Feed the tracker when a (non-resume) lane lands its first
        token; an objective breach bumps the counter and triggers the
        flight recorder."""
        ttft = max(s.t_first - s.req.arrival, 0.0)
        if self.slo.observe_ttft(s.t_first, ttft, s.req.priority):
            self._c_ttft_breach.inc()
            fr = self._obs.recorder
            if fr is not None:
                obj = self.slo.policy.ttft_objective_s(s.req.priority)
                fr.breach(s.t_first, "ttft_breach", rid=s.req.rid,
                          ttft_ms=round(ttft * 1e3, 3),
                          objective_ms=round(obj * 1e3, 3))

    def _preempt_below(self, priority: int) -> bool:
        """Evict the weakest running lane whose STATIC class is strictly
        below `priority` (lowest class first, most recently admitted
        first within a class — oldest work is disturbed last). Static
        compare: an aged low-priority request earns admission rank, not
        the right to evict. Returns True when a lane was preempted."""
        top = self.runner.prefill_buckets[-1]
        cands = [i for i, s in enumerate(self._slots)
                 if s is not None and s.prefill_pos < 0
                 and s.req.priority < priority
                 # without chunked admission a resume whose recompute
                 # suffix outgrew the bucket grid could never re-admit
                 # (cached blocks may be evicted meanwhile) — skip it
                 and (self.runner.prefill_chunk or s.pos <= top)]
        if not cands:
            return False
        victim = min(cands, key=lambda i: (self._slots[i].req.priority,
                                           -self._slots[i].t_admit))
        return self.preempt(victim) is not None

    def admit(self) -> None:
        """Form same-bucket groups from the queue — scanned in
        (effective priority desc, submit order), see `_eff_priority` —
        and admit each group in one batched prefill dispatch, while
        lanes and blocks last. A request whose prefix overlaps a
        groupmate's beyond what the cache already holds is deferred one
        group (see `_defer_for_group_prefix`) so it shares blocks
        instead of recomputing them. When the top waiting class
        outranks a running lane and admission is blocked — every lane
        busy, or the pool can't cover the head request's reservation —
        the weakest strictly-lower lane is preempted (KV parked in the
        cached-free pool, resume queued; see `preempt`) and admission
        retries.

        A prompt whose suffix exceeds the largest prefill bucket is
        routed to chunked admission instead: its blocks and budget are
        reserved now, but the prefill itself runs one fixed-budget
        chunk per engine step (`prefill_step`), interleaved with decode
        dispatches so running lanes aren't starved during a long
        admission. With chunking disabled (prefill_chunk=0) the same
        suffix is rejected with an actionable error (suffix_bucket)
        rather than falling through to an oversized jit variant."""
        self._shed_hopeless()
        while True:
            if self._queue and not self._free_slots():
                top = max(r.priority for r in self._queue)
                if not self._preempt_below(top):
                    return
            free = self._free_slots()
            if not free or not self._queue:
                return
            cap = min(len(free), self.runner.prefill_max_batch)
            plans: List[_Plan] = []
            bucket = None
            chunked = False
            taken: set = set()            # id() of admitted requests
            order = self._admission_order()
            j = 0
            while j < len(order) and len(plans) < cap:
                req = order[j]
                match = self._match(req)  # peek: takes no references
                if self._defer_for_group_prefix(req, match, plans):
                    j += 1
                    continue
                suf = len(req.prompt) - min(
                    match.tokens(self.block_size), len(req.prompt) - 1)
                if (self.runner.prefill_chunk
                        and suf > self.runner.prefill_buckets[-1]):
                    if plans:             # needs its own admission
                        j += 1
                        continue
                    plan = self._reserve(req, free[0], match)
                    if plan is None:
                        if self._preempt_below(req.priority):
                            continue      # blocks freed; retry the head
                        break             # pool exhausted; retry later
                    taken.add(id(req))
                    self._begin_chunked(plan)
                    chunked = True
                    break                 # slot map changed; reform
                b = self.runner.suffix_bucket(suf)
                if bucket is not None and b != bucket:
                    j += 1
                    continue
                plan = self._reserve(req, free[len(plans)], match)
                if plan is None:
                    if not plans and self._preempt_below(req.priority):
                        continue          # blocks freed; retry the head
                    break                 # pool exhausted; retry later
                taken.add(id(req))
                plans.append(plan)
                bucket = b
                j += 1
            if taken:
                # skipped requests keep their queue positions: the
                # queue itself stays in submit order (take_queued and
                # drain preserve FCFS), only the admitted leave it
                self._queue = deque(r for r in self._queue
                                    if id(r) not in taken)
            if plans:
                self._dispatch(plans)
            elif not chunked:
                return

    def _dispatch(self, plans: List[_Plan]) -> None:
        rows = [PrefillRow(tokens=np.asarray(p.req.prompt, np.int32),
                           cached_len=p.cached, slot=p.slot,
                           table_row=p.table_row,
                           sampling=p.req.sampling) for p in plans]
        first, lp, alt = self.runner.prefill(rows)  # blocks: TTFT covers it
        t_first = self._now()
        self._c_admitted.inc(len(plans))
        for i, (p, tok, tok_lp) in enumerate(zip(plans, first, lp)):
            P = len(p.req.prompt)
            sp = p.req.sampling
            if self.prefix_cache:
                self.allocator.register_prefix(
                    p.req.prompt, [int(b) for b in p.table_row])
            self.runner.write_table(p.slot, p.table_row)
            self.runner.set_sampling(p.slot, sp)
            stops = [list(s) for s in sp.stop]
            s = _Slot(
                req=p.req, sp=sp, stops=stops, table_row=p.table_row,
                pos=P, pending=int(tok), out=[],
                hist=[int(t) for t in p.req.prompt],
                t_admit=p.t_admit, t_first=t_first, cached=p.cached,
                n_blocks=p.n_blocks, prompt_blocks=p.n_blocks,
                budget=p.budget, cow_block=p.cow_block,
                cow_index=p.cow_index,
                lps=[] if sp.logprobs else None,
                alts=[] if sp.logprobs else None)
            self._slots[p.slot] = s
            rec = self._resume_state.pop(p.req.rid, None)
            if rec is not None:
                self._resume_slot(p.slot, s, rec, int(tok))
                continue
            if self.slo is not None:
                self._observe_ttft(s)
            if self._stop_cut(s, [int(tok)]) is not None:
                s.stopped = True
            self._emit(s, [int(tok)], [float(tok_lp)],
                       self._slice_alt(s, alt, i))
            self._maybe_finish(p.slot)

    # ------------------------------------------------------------------
    # chunked prefill (long-context admission)
    # ------------------------------------------------------------------

    def _begin_chunked(self, plan: _Plan) -> None:
        """Claim a lane for a long prompt WITHOUT prefilling it: blocks
        and budget are already reserved by `_reserve`; the prefill runs
        one `runner.prefill_chunk`-token chunk per `prefill_step` call.
        The slot sits out decode/verify (prefill_pos >= 0) until the
        final chunk lands its first token."""
        p = plan
        P = len(p.req.prompt)
        sp = p.req.sampling
        # NOTE: the runner's persistent table row stays NULL until the
        # final chunk lands (prefill dispatches carry their table row
        # per-row): decode/verify steps running between chunks write
        # their inactive-lane junk to the null sink, exactly like an
        # evicted slot — writing the real row now would let them
        # corrupt this prompt's block 0.
        self.runner.set_sampling(p.slot, sp)
        self._c_admitted.inc()
        start = min(p.cached, P - 1)
        chunk = self.runner.prefill_chunk
        s = _Slot(
            req=p.req, sp=sp, stops=[list(ss) for ss in sp.stop],
            table_row=p.table_row, pos=P, pending=-1, out=[],
            hist=[int(t) for t in p.req.prompt],
            t_admit=p.t_admit, t_first=0.0, cached=p.cached,
            n_blocks=p.n_blocks, prompt_blocks=p.n_blocks,
            budget=p.budget, cow_block=p.cow_block,
            cow_index=p.cow_index,
            lps=[] if sp.logprobs else None,
            alts=[] if sp.logprobs else None,
            prefill_pos=start,
            prefill_chunks_total=-(-(P - start) // chunk))
        self._slots[p.slot] = s

    def prefill_step(self) -> bool:
        """Advance ONE in-flight chunked prefill by one chunk (round-
        robin across slots so concurrent long admissions share the
        step budget fairly). Each chunk is a resumed suffix prefill:
        the previous chunks' KV already sit in this slot's pool blocks,
        so cached_len picks up exactly where they stopped. The sampled
        token of a non-final chunk is discarded (its logits sit mid-
        prompt); the final chunk emits the real first token. Returns
        True when a chunk was dispatched."""
        pending = [i for i, s in enumerate(self._slots)
                   if s is not None and s.prefill_pos >= 0]
        if not pending:
            return False
        i = pending[self._chunk_rr % len(pending)]
        self._chunk_rr += 1
        s = self._slots[i]
        P = len(s.req.prompt)
        c = s.prefill_pos
        clen = min(self.runner.prefill_chunk, P - c)
        final = c + clen == P
        row = PrefillRow(tokens=np.asarray(s.req.prompt[:c + clen],
                                           np.int32),
                         cached_len=c, slot=i, table_row=s.table_row,
                         sampling=s.sp)
        first, lp, alt = self.runner.prefill(
            [row], resume=s.prefill_chunks > 0,
            chunk=(s.prefill_chunks, s.prefill_chunks_total))
        s.prefill_chunks += 1
        if not final:
            s.prefill_pos = c + clen
            return True
        if self.prefix_cache:
            self.allocator.register_prefix(
                s.req.prompt, [int(b) for b in s.table_row])
        self.runner.write_table(i, s.table_row)
        s.prefill_pos = -1
        rec = self._resume_state.pop(s.req.rid, None)
        if rec is not None:               # a resume whose recompute
            self._resume_slot(i, s, rec, int(first[0]))   # went chunked
            return True
        s.pending = int(first[0])
        s.t_first = self._now()
        if self.slo is not None:
            self._observe_ttft(s)
        if self._stop_cut(s, [s.pending]) is not None:
            s.stopped = True
        self._emit(s, [s.pending], [float(lp[0])],
                   self._slice_alt(s, alt, 0))
        self._maybe_finish(i)
        return True

    # ------------------------------------------------------------------
    # preemption + bit-identical resume
    # ------------------------------------------------------------------

    def preempt(self, slot_id: Optional[int] = None) -> Optional[int]:
        """Evict a running lane mid-generation, keeping its computed KV
        warm: every FULL block of prompt+generated KV (positions
        0..pos-1 = hist[:pos]) is published in the prefix index FIRST,
        so the teardown decrefs park those blocks in the cached-free
        pool instead of freeing them blind. A resume request — prompt'
        = hist[:pos], the tokens whose KV was already computed, at the
        ORIGINAL class and submit order — re-enters the queue, and the
        original outputs/timestamps stash in `_resume_state` until its
        re-admission restores them (`_resume_slot`). Resume is then a
        plain admission: full blocks come back as prefix-cache hits and
        only the partial tail block (plus the last position, which
        `_reserve` always recomputes) costs prefill; if pressure
        evicted the parked blocks meanwhile, resume just recomputes
        more — still bit-identical, never wrong.

        With slot_id None the weakest lane is chosen: lowest static
        class first, most recently admitted within a class. Lanes still
        mid-chunked-prefill are not preemptible (no first token yet),
        nor — without chunked admission — lanes whose recompute suffix
        outgrew the prefill bucket grid. Returns the evicted slot id,
        or None when no lane is preemptible."""
        if slot_id is None:
            top = self.runner.prefill_buckets[-1]
            cands = [i for i, s in enumerate(self._slots)
                     if s is not None and s.prefill_pos < 0
                     and (self.runner.prefill_chunk or s.pos <= top)]
            if not cands:
                return None
            slot_id = min(cands,
                          key=lambda i: (self._slots[i].req.priority,
                                         -self._slots[i].t_admit))
        s = self._slots[slot_id]
        if s is None or s.prefill_pos >= 0:
            return None
        # KV exists for positions 0..pos-1; park the full blocks
        if self.prefix_cache:
            self.allocator.register_prefix(
                np.asarray(s.hist[:s.pos], np.int32),
                [int(b) for b in s.table_row])
        self._resume_state[s.req.rid] = _ResumeState(
            req=s.req, sp=s.sp, stops=s.stops, out=s.out, hist=s.hist,
            pos=s.pos, pending=s.pending, t_admit=s.t_admit,
            t_first=s.t_first, cached=s.cached, lps=s.lps, alts=s.alts)
        # the resume request's budget math matches the uninterrupted
        # run: ceil((pos + remaining) / bs) == ceil((P + max_new) / bs)
        remaining = len(s.req.prompt) + s.sp.max_new_tokens - s.pos
        resume = Request(
            rid=s.req.rid, prompt=np.asarray(s.hist[:s.pos], np.int32),
            arrival=s.req.arrival,
            sampling=dataclasses.replace(s.sp,
                                         max_new_tokens=remaining),
            priority=s.req.priority, trace=s.req.trace)
        resume._seq = getattr(s.req, "_seq", 0)
        resume._t_submit = getattr(s.req, "_t_submit", self._now())
        # teardown mirrors _maybe_finish (no Completion): indexed
        # blocks park cached-free, the rest return to the free list
        for b in s.table_row:
            if b != NULL_BLOCK:
                self.allocator.decref(int(b))
        if s.cow_block is not None:       # reserved but never written
            self.allocator.decref(s.cow_block)
        self._reserved_budget -= s.budget
        self.runner.clear_table(slot_id)
        self._slots[slot_id] = None
        self._queue.append(resume)
        self.preemptions += 1
        self._c_preempted.inc()
        fr = self._obs.recorder
        if fr is not None:                # preemption-storm detection
            fr.note_preempt(self._now())
        if self._obs.enabled:
            self._obs.instant(slot_id, "preempt", "scheduler",
                              self._now(), rid=s.req.rid, pos=s.pos,
                              generated=len(s.out),
                              priority=s.req.priority)
        return slot_id

    def _resume_slot(self, slot_id: int, s: _Slot, rec: _ResumeState,
                     tok: int) -> None:
        """Re-arm a freshly admitted resume lane with its pre-preemption
        identity: original request/sampling (so the max_new finish check
        and Completion fields see the uninterrupted view), accumulated
        outputs, and timestamps (TTFT is unchanged by preemption). The
        recomputed token is NOT re-emitted — it was already emitted
        before the preemption; position-keyed sampling makes the resume
        prefill (keyed at pos-1, like the dispatch it replays) land the
        very same token, which is asserted: it IS the bit-identity
        invariant."""
        assert s.pos == rec.pos, (s.pos, rec.pos)
        assert tok == rec.pending, (
            f"resume replay diverged for rid {rec.req.rid}: "
            f"recomputed {tok} != pending {rec.pending} at {rec.pos}")
        s.req = rec.req
        s.sp = rec.sp
        s.stops = rec.stops
        s.out = rec.out
        s.hist = rec.hist
        s.pending = rec.pending
        s.t_admit = rec.t_admit
        s.t_first = rec.t_first
        s.cached = rec.cached
        s.lps = rec.lps
        s.alts = rec.alts
        self.resumes += 1
        self._c_resumed.inc()
        if self._obs.enabled:
            self._obs.instant(slot_id, "resume", "scheduler",
                              self._now(), rid=rec.req.rid, pos=rec.pos,
                              generated=len(rec.out))

    # ------------------------------------------------------------------
    # emission + unified stop handling (eos == a one-token stop seq)
    # ------------------------------------------------------------------

    @staticmethod
    def _slice_alt(s: _Slot, alt, row: int, positions=None):
        """Per-request view of a runner alt side output: the request's
        own k columns (k = sp.logprobs <= the compiled width) at `row`
        (and each of `positions` for the (B, T, K) verify layout).
        None when the request didn't ask or the dispatch carried none."""
        if alt is None or not s.sp.logprobs:
            return None
        ids, lps = alt
        k = s.sp.logprobs
        if positions is None:
            return [(ids[row, :k].tolist(), lps[row, :k].tolist())]
        return [(ids[row, t, :k].tolist(), lps[row, t, :k].tolist())
                for t in positions]

    def _emit(self, s: _Slot, tokens: List[int],
              lps: Optional[List[float]] = None,
              alts: Optional[List[Tuple[List[int],
                                        List[float]]]] = None) -> None:
        """Append generated tokens to the output AND the proposer
        history in one place (hist == prompt + out is the proposer's
        input invariant), record logprobs / top-k alternatives if the
        request asked, and fire the streaming callback."""
        s.out.extend(tokens)
        s.hist.extend(tokens)
        self._c_tokens.inc(len(tokens))
        if s.lps is not None and lps is not None:
            s.lps.extend(lps)
        have_alt = s.alts is not None and alts is not None
        if have_alt:
            s.alts.extend(alts)
        if self.on_event is not None:
            self.on_event(StreamEvent(
                rid=s.req.rid, tokens=list(tokens),
                logprobs=list(lps) if (s.lps is not None and lps) else None,
                top_ids=[a[0] for a in alts] if have_alt else None,
                top_logprobs=[a[1] for a in alts] if have_alt else None))

    def _stop_cut(self, s: _Slot, new_tokens: List[int]) -> Optional[int]:
        """Earliest 1-based index into `new_tokens` at which a stop
        sequence completes, scanning the GENERATED output only (s.out,
        not yet extended, plus the candidate tokens); None if no stop
        fires. Stop sequences may span previously emitted tokens and
        the new chunk, but never reach into the prompt."""
        if not s.stops:
            return None
        longest = max(len(ss) for ss in s.stops)
        # the last (longest-1) already-emitted tokens are the only old
        # context a newly-completing stop can reach back into
        tail = s.out[-(longest - 1):] if longest > 1 else []
        window = tail + list(new_tokens)
        base = len(tail)
        for j in range(1, len(new_tokens) + 1):
            end = base + j
            for ss in s.stops:
                L = len(ss)
                if L <= len(s.out) + j and window[end - L:end] == ss:
                    return j
        return None

    # ------------------------------------------------------------------
    # incremental block claim / release (the draft reservation)
    # ------------------------------------------------------------------

    def _claim_blocks(self, slot_id: int, last_pos: int) -> int:
        """Bind physical blocks so the table covers a write at
        `last_pos`, drawing them from the slot's reserved budget.
        Cannot fail: admission guaranteed the budget, and the global
        reserved counter kept later admissions from eating it.
        Returns the number of blocks claimed."""
        s = self._slots[slot_id]
        need = last_pos // self.block_size + 1
        claimed = 0
        while s.n_blocks < need:
            got = self.allocator.alloc(1)
            assert got is not None and s.budget > 0, \
                "block budget invariant violated"
            s.table_row[s.n_blocks] = got[0]
            s.n_blocks += 1
            s.budget -= 1
            self._reserved_budget -= 1
            claimed += 1
        if claimed:
            self.runner.write_table(slot_id, s.table_row)
        return claimed

    def _trim_blocks(self, slot_id: int, last_pos: int) -> int:
        """Release bound blocks past the last committed write at
        `last_pos` back to the allocator and return them to the slot's
        budget — the rollback of `_claim_blocks` for a rejected draft
        suffix. Never trims into the prompt. Returns #blocks freed."""
        s = self._slots[slot_id]
        keep = max(last_pos // self.block_size + 1, s.prompt_blocks)
        freed = 0
        while s.n_blocks > keep:
            s.n_blocks -= 1
            self.allocator.decref(int(s.table_row[s.n_blocks]))
            s.table_row[s.n_blocks] = NULL_BLOCK
            s.budget += 1
            self._reserved_budget += 1
            freed += 1
        if freed:
            self.runner.write_table(slot_id, s.table_row)
        return freed

    def _fire_cow(self, slot_id: int) -> None:
        """A slot about to write into a still-shared first-divergent
        block swaps in its reserved private copy first (lazy COW)."""
        s = self._slots[slot_id]
        if s.cow_block is None:
            return
        old = int(s.table_row[s.cow_index])
        self.runner.copy_block(old, s.cow_block)
        self.allocator.decref(old)
        s.table_row[s.cow_index] = s.cow_block
        self.runner.write_table(slot_id, s.table_row)
        s.cow_block = None

    # ------------------------------------------------------------------
    # decode-side lifecycle
    # ------------------------------------------------------------------

    def prepare_decode(self):
        """Assemble the plain one-token decode batch; fire pending lazy
        copy-on-writes and claim the block each lane's write needs.
        Returns (tokens, positions, active slot ids) or None when no
        lane is active. Lanes mid-way through a chunked prefill
        (prefill_pos >= 0) have no first token yet and sit out."""
        active = [i for i, s in enumerate(self._slots)
                  if s is not None and s.prefill_pos < 0]
        if not active:
            return None
        tokens = np.zeros(self.num_slots, np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        for i in active:
            s = self._slots[i]
            self._fire_cow(i)
            self._claim_blocks(i, s.pos)
            tokens[i] = s.pending
            positions[i] = s.pos
        return tokens, positions, active

    def consume(self, active: List[int], next_tok: np.ndarray,
                lp: Optional[np.ndarray] = None, alt=None) -> None:
        """Advance each active lane with its sampled token; finish and
        evict lanes that hit max_new_tokens or a stop sequence."""
        if self._obs.enabled:
            self._obs.annotate_step(active=len(active),
                                    emitted=len(active))
        for i in active:
            s = self._slots[i]
            tok = int(next_tok[i])
            s.pos += 1
            s.pending = tok
            if self._stop_cut(s, [tok]) is not None:
                s.stopped = True
            self._emit(s, [tok],
                       [float(lp[i])] if lp is not None else None,
                       self._slice_alt(s, alt, i))
            self._maybe_finish(i)

    # ------------------------------------------------------------------
    # speculative decoding: propose -> verify -> accept / rollback
    # ------------------------------------------------------------------

    def prepare_verify(self):
        """Assemble a verify batch of per-lane draft chains
        [pending, d_1 .. d_k] (k from each lane's proposer, capped so
        the chain can never emit past max_new_tokens), claim the blocks
        each chain would write, and pad to the runner's chain bucket.
        Returns (tokens (num_slots, T), positions, counts, active) — or
        None when no lane proposed anything, so the engine falls back
        to the plain decode dispatch at zero overhead. Lanes mid-way
        through a chunked prefill sit out (see prepare_decode)."""
        active = [i for i, s in enumerate(self._slots)
                  if s is not None and s.prefill_pos < 0]
        if not active:
            return None
        drafts: Dict[int, List[int]] = {}
        max_chain = 1
        for i in active:
            s = self._slots[i]
            k = min(self.speculate, s.sp.max_new_tokens - len(s.out) - 1)
            d = self._proposers[i].propose(s.hist, k) if k > 0 else []
            # clamp: the propose(history, k) seam must not let an
            # over-eager proposer overflow the chain bucket, emit past
            # max_new_tokens, or outrun the block budget
            drafts[i] = list(d)[:max(k, 0)]
            max_chain = max(max_chain, 1 + len(drafts[i]))
        if max_chain == 1:
            return None
        T = self.runner.chain_bucket(max_chain)
        tokens = np.zeros((self.num_slots, T), np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        counts = np.zeros(self.num_slots, np.int32)
        for i in active:
            s = self._slots[i]
            chain = [s.pending] + drafts[i]
            self._fire_cow(i)
            self._claim_blocks(i, s.pos + len(chain) - 1)
            tokens[i, :len(chain)] = chain
            positions[i] = s.pos
            counts[i] = len(chain)
            self.proposed_tokens += len(drafts[i])
            self._c_proposed.inc(len(drafts[i]))
        if self._obs.enabled:
            self._last_proposed = {i: len(drafts[i]) for i in active}
        return tokens, positions, counts, active

    def consume_verify(self, active: List[int], out_tok: np.ndarray,
                       accept: np.ndarray,
                       lp: Optional[np.ndarray] = None, alt=None) -> None:
        """Accept/rollback after a verify dispatch. out_tok: (num_slots,
        T) emitted tokens at every chain position (model argmax for
        greedy lanes; accepted drafts + the residual-resampled
        correction or bonus for sampled lanes); accept: (num_slots,)
        accepted draft counts, both computed on-device. Per lane: take
        the accepted run plus the one correction/bonus token, truncate
        it at the first completed stop sequence, commit recurrent state
        at the truncated length through the runner, free the blocks a
        rejected (or stop-cut) suffix claimed, advance, and finish
        lanes that hit max_new_tokens or a stop."""
        commit_idx = np.zeros(self.num_slots, np.int32)
        plan: Dict[int, tuple] = {}
        for i in active:
            s = self._slots[i]
            a = int(accept[i])
            emitted = [int(out_tok[i, t]) for t in range(a + 1)]
            lps = ([float(lp[i, t]) for t in range(a + 1)]
                   if lp is not None else None)
            cut = self._stop_cut(s, emitted)
            if cut is not None:
                emitted = emitted[:cut]
                if lps is not None:
                    lps = lps[:cut]
            plan[i] = (emitted, lps, cut is not None)
            commit_idx[i] = len(emitted)
            # accepted = drafts that actually materialized as output
            # (drafts agreeing past a truncating stop don't count)
            acc = len(emitted) - 1
            self.accepted_tokens += acc
            self._c_accepted.inc(acc)
            if self._obs.enabled and self._accept_window:
                self._h_accept.observe(acc)
                self._h_accept_slot[i].observe(acc)
                win = self._accept_window[i]
                win.append((self._last_proposed.get(i, 0), acc))
                prop_sum = sum(p for p, _ in win)
                if prop_sum > 0:
                    self._g_accept_rate[i].set(
                        sum(a for _, a in win) / prop_sum)
        if self._obs.enabled:
            self._obs.annotate_step(
                active=len(active),
                emitted=sum(len(plan[i][0]) for i in active),
                accept_lens=[len(plan[i][0]) - 1 for i in active])
        # restore recurrent slot state at each lane's accepted
        # (stop-truncated) length BEFORE host bookkeeping (a no-op for
        # pure-attention archs)
        self.runner.commit(commit_idx)
        for i in active:
            emitted, lps, stopped = plan[i]
            s = self._slots[i]
            if stopped:
                s.stopped = True
            self._emit(s, emitted, lps,
                       self._slice_alt(s, alt, i, range(len(emitted))))
            s.pos += len(emitted)
            s.pending = emitted[-1]
            # rejected suffix: free exactly the blocks it claimed
            self._trim_blocks(i, s.pos - 1)
            self._maybe_finish(i)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def _maybe_finish(self, slot_id: int) -> None:
        s = self._slots[slot_id]
        done = s.stopped or len(s.out) >= s.sp.max_new_tokens
        if not done:
            return
        completion = Completion(
            rid=s.req.rid, prompt_len=len(s.req.prompt),
            tokens=np.asarray(s.out, np.int32), arrival=s.req.arrival,
            t_admit=s.t_admit, t_first_token=s.t_first,
            t_done=self._now(),
            cached_tokens=min(s.cached, len(s.req.prompt) - 1),
            finish_reason="stop" if s.stopped else "length",
            logprobs=(np.asarray(s.lps, np.float32)
                      if s.lps is not None else None),
            top_ids=(np.asarray([a[0] for a in s.alts], np.int32)
                     if s.alts is not None else None),
            top_logprobs=(np.asarray([a[1] for a in s.alts], np.float32)
                          if s.alts is not None else None))
        self.completions.append(completion)
        self._c_finished[completion.finish_reason].inc()
        if self.slo is not None:
            lat = max(completion.t_done - completion.arrival, 0.0)
            if self.slo.observe_latency(completion.t_done, lat,
                                        s.req.priority):
                self._c_lat_breach.inc()
                fr = self._obs.recorder
                if fr is not None:
                    fr.breach(completion.t_done, "latency_breach",
                              rid=completion.rid,
                              latency_ms=round(lat * 1e3, 3))
            n = len(completion.tokens)
            if n > 1:
                self.slo.observe_tpot(
                    completion.t_done,
                    (completion.t_done - s.t_first) / (n - 1),
                    s.req.priority)
        if self._obs.enabled:
            trace = s.req.trace or {}
            t_q = trace.get("queued", s.req.arrival)
            rid = completion.rid
            self._obs.async_span(
                f"req {rid} queued", "queue", rid, t_q, s.t_admit,
                routed="routed" in trace)
            self._obs.span(
                slot_id, f"req {rid}", "request", s.t_admit,
                completion.t_done, rid=rid,
                prompt_len=completion.prompt_len,
                cached_tokens=completion.cached_tokens,
                generated=len(completion.tokens),
                finish_reason=completion.finish_reason)
            self._obs.span(slot_id, "prefill", "phase",
                           s.t_admit, s.t_first)
            self._obs.span(slot_id, "decode", "phase",
                           s.t_first, completion.t_done)
        for b in s.table_row:
            if b != NULL_BLOCK:
                self.allocator.decref(int(b))
        if s.cow_block is not None:       # reserved but never written
            self.allocator.decref(s.cow_block)
        self._reserved_budget -= s.budget
        self.runner.clear_table(slot_id)
        self._slots[slot_id] = None
        if self.on_event is not None:
            self.on_event(StreamEvent(rid=completion.rid, tokens=[],
                                      done=True, completion=completion))
