"""Scheduler: queue, admission policy, request lifecycle, eviction.

The top layer of the serving engine (scheduler -> block manager ->
runner). It owns every request-level decision and no device state:

  * FCFS queue with bucketed batch formation — admission picks the
    oldest waiting request, peeks its prefix-cache match to find its
    suffix-length bucket, then collects further queued requests that
    fall in the SAME bucket (bounded queue-jumping: other buckets keep
    their place) until slots, blocks, or the prefill batch width run
    out. The whole group is admitted in ONE `runner.prefill` dispatch.
  * conservative block reservation — ceil((prompt + max_new) /
    block_size) blocks per request minus fully-shared prefix blocks, so
    an admitted request can never deadlock on cache memory. A shared
    first-divergent block is counted as needing its copy-on-write
    replacement up front, so the later copy can never fail.
  * prefix sharing + copy-on-write — matched full blocks are shared by
    refcount; a partially-matched (first divergent) block is shared and
    then copied before its first write: eagerly at admission when the
    prompt itself diverges mid-block, lazily at the first decode step
    when the whole prompt was cached and only generation writes into it.
  * lifecycle + eviction — finished sequences (max_new_tokens or eos)
    are evicted: their table row is nulled, their lane freed, and every
    block reference dropped (shared prompt blocks survive in the block
    manager's cached-free pool for future hits).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

import numpy as np

from repro.serving.block_manager import (NULL_BLOCK, BlockAllocator,
                                         PrefixMatch)
from repro.serving.runner import ModelRunner, PrefillRow


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0          # seconds on the engine clock (open loop)
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    arrival: float
    t_admit: float
    t_first_token: float
    t_done: float
    cached_tokens: int = 0        # prompt tokens served from the prefix cache


@dataclasses.dataclass
class _Slot:
    req: Request
    table_row: np.ndarray         # (max_blocks,) int32, NULL padded
    pos: int                      # position of the next token to feed
    pending: int                  # token to feed at `pos`
    out: List[int]
    t_admit: float
    t_first: float
    cached: int                   # prefix-cache hit tokens at admission
    cow_block: Optional[int]      # reserved private copy for the shared
    cow_index: int = -1           # first-divergent block (lazy COW)


@dataclasses.dataclass
class _Plan:
    """A reserved admission: blocks held, table row built, ready for one
    row of a batched prefill dispatch."""
    req: Request
    table_row: np.ndarray
    slot: int
    cached: int
    cow_block: Optional[int]
    cow_index: int
    t_admit: float

    @property
    def suffix_len(self) -> int:
        return len(self.req.prompt) - min(self.cached,
                                          len(self.req.prompt) - 1)


class Scheduler:
    """Request lifecycle over a BlockAllocator and a ModelRunner."""

    def __init__(self, allocator: BlockAllocator, runner: ModelRunner, *,
                 num_slots: int, block_size: int, max_blocks_per_seq: int,
                 max_seq_len: int, prefix_cache: bool,
                 now_fn: Callable[[], float]):
        self.allocator = allocator
        self.runner = runner
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_seq_len = max_seq_len
        self.prefix_cache = prefix_cache
        self._now = now_fn
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * num_slots
        self.completions: List[Completion] = []
        self.reset_stats()

    def reset_stats(self) -> None:
        self.prompt_tokens = 0
        self.cached_prompt_tokens = 0
        self.prefix_hit_requests = 0

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 (the "
                f"first token is sampled from the prefill logits)")
        if len(req.prompt) + req.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new_tokens} exceeds "
                f"max_seq_len {self.max_seq_len}")
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _match(self, req: Request) -> PrefixMatch:
        if not self.prefix_cache:
            return PrefixMatch([], None, 0)
        return self.allocator.match_prefix(req.prompt)

    def _reserve(self, req: Request, slot: int,
                 match: PrefixMatch) -> Optional[_Plan]:
        """Share the matched prefix blocks, allocate the rest, build the
        table row. Returns None (nothing held) if the pool is short."""
        P = len(req.prompt)
        total = -(-(P + req.max_new_tokens) // self.block_size)
        f = len(match.full_blocks)
        self.allocator.share(match)       # revive + hold before alloc
        fresh = self.allocator.alloc(total - f)
        if fresh is None:
            self.allocator.unshare(match)
            return None
        row = np.full(self.max_blocks_per_seq, NULL_BLOCK, np.int32)
        row[:f] = match.full_blocks
        cached = f * self.block_size + match.partial_len
        cow_block, cow_index = None, -1
        rest = fresh
        if match.partial_block is not None:
            if match.partial_len == P - f * self.block_size:
                # whole prompt cached up to this block: keep sharing it;
                # generation's first write will trigger the lazy copy
                row[f] = match.partial_block
                cow_block, cow_index = fresh[0], f
            else:
                # prompt diverges mid-block: copy now, prefill writes it
                self.runner.copy_block(match.partial_block, fresh[0])
                self.allocator.decref(match.partial_block)
                row[f] = fresh[0]
            rest = fresh[1:]
            row[f + 1:f + 1 + len(rest)] = rest
        else:
            row[f:f + len(fresh)] = fresh
        self.prompt_tokens += P
        self.cached_prompt_tokens += min(cached, P - 1)
        if cached > 0:
            self.prefix_hit_requests += 1
            self.allocator.touch(match.full_blocks)
        return _Plan(req=req, table_row=row, slot=slot, cached=cached,
                     cow_block=cow_block, cow_index=cow_index,
                     t_admit=self._now())

    def admit(self) -> None:
        """Form same-bucket groups from the queue and admit each group
        in one batched prefill dispatch, while lanes and blocks last."""
        while True:
            free = self._free_slots()
            if not free or not self._queue:
                return
            cap = min(len(free), self.runner.prefill_max_batch)
            plans: List[_Plan] = []
            bucket = None
            skipped: List[Request] = []
            while self._queue and len(plans) < cap:
                req = self._queue[0]
                match = self._match(req)  # peek: takes no references
                suf = len(req.prompt) - min(
                    match.tokens(self.block_size), len(req.prompt) - 1)
                b = self.runner.suffix_bucket(suf)
                if bucket is not None and b != bucket:
                    skipped.append(self._queue.popleft())
                    continue
                plan = self._reserve(req, free[len(plans)], match)
                if plan is None:
                    break                 # pool exhausted; retry later
                self._queue.popleft()
                plans.append(plan)
                bucket = b
            for req in reversed(skipped):
                self._queue.appendleft(req)
            if not plans:
                return
            self._dispatch(plans)

    def _dispatch(self, plans: List[_Plan]) -> None:
        rows = [PrefillRow(tokens=np.asarray(p.req.prompt, np.int32),
                           cached_len=p.cached, slot=p.slot,
                           table_row=p.table_row) for p in plans]
        first = self.runner.prefill(rows)   # blocks: TTFT covers it
        t_first = self._now()
        for p, tok in zip(plans, first):
            P = len(p.req.prompt)
            if self.prefix_cache:
                self.allocator.register_prefix(
                    p.req.prompt, [int(b) for b in p.table_row])
            self.runner.write_table(p.slot, p.table_row)
            self._slots[p.slot] = _Slot(
                req=p.req, table_row=p.table_row, pos=P, pending=int(tok),
                out=[int(tok)], t_admit=p.t_admit, t_first=t_first,
                cached=p.cached, cow_block=p.cow_block,
                cow_index=p.cow_index)
            self._maybe_finish(p.slot)

    # ------------------------------------------------------------------
    # decode-side lifecycle
    # ------------------------------------------------------------------

    def prepare_decode(self):
        """Assemble the decode batch; fire pending lazy copy-on-writes
        (a slot about to write into a still-shared first-divergent block
        swaps in its reserved private copy first). Returns (tokens,
        positions, active slot ids) or None when no lane is active."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return None
        tokens = np.zeros(self.num_slots, np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        for i in active:
            s = self._slots[i]
            if s.cow_block is not None:
                old = int(s.table_row[s.cow_index])
                self.runner.copy_block(old, s.cow_block)
                self.allocator.decref(old)
                s.table_row[s.cow_index] = s.cow_block
                self.runner.write_table(i, s.table_row)
                s.cow_block = None
            tokens[i] = s.pending
            positions[i] = s.pos
        return tokens, positions, active

    def consume(self, active: List[int], next_tok: np.ndarray) -> None:
        """Advance each active lane with its sampled token; finish and
        evict lanes that hit max_new_tokens or eos."""
        for i in active:
            s = self._slots[i]
            s.pos += 1
            s.pending = int(next_tok[i])
            s.out.append(s.pending)
            self._maybe_finish(i)

    def _maybe_finish(self, slot_id: int) -> None:
        s = self._slots[slot_id]
        done = (len(s.out) >= s.req.max_new_tokens
                or (s.req.eos_id is not None and s.out
                    and s.out[-1] == s.req.eos_id))
        if not done:
            return
        self.completions.append(Completion(
            rid=s.req.rid, prompt_len=len(s.req.prompt),
            tokens=np.asarray(s.out, np.int32), arrival=s.req.arrival,
            t_admit=s.t_admit, t_first_token=s.t_first,
            t_done=self._now(), cached_tokens=min(s.cached,
                                                  len(s.req.prompt) - 1)))
        for b in s.table_row:
            if b != NULL_BLOCK:
                self.allocator.decref(int(b))
        if s.cow_block is not None:       # reserved but never written
            self.allocator.decref(s.cow_block)
        self.runner.clear_table(slot_id)
        self._slots[slot_id] = None
