"""Scheduler: queue, admission policy, request lifecycle, eviction,
and the propose/accept/rollback half of speculative decoding.

The top layer of the serving engine (scheduler -> block manager ->
runner). It owns every request-level decision and no device state:

  * FCFS queue with bucketed batch formation — admission picks the
    oldest waiting request, peeks its prefix-cache match to find its
    suffix-length bucket, then collects further queued requests that
    fall in the SAME bucket (bounded queue-jumping: other buckets keep
    their place) until slots, blocks, or the prefill batch width run
    out. The whole group is admitted in ONE `runner.prefill` dispatch.
  * incremental block allocation under a conservative budget —
    admission allocates only the prompt's blocks and RESERVES (but does
    not bind) the ceil((prompt + max_new) / block_size) remainder as a
    per-slot budget; generation claims physical blocks lazily as
    positions cross block boundaries and a draft chain claims the
    blocks its tokens would write up front. The global reserved-budget
    counter keeps admission honest (a live sequence can always claim
    its full budget — no deadlock), while unclaimed blocks stay in the
    allocator's pools, so cached prefix blocks survive longer under
    pressure than with bind-everything-at-admission.
  * prefix sharing + copy-on-write — matched full blocks are shared by
    refcount; a partially-matched (first divergent) block is shared and
    then copied before its first write: eagerly at admission when the
    prompt itself diverges mid-block, lazily at the first decode step
    when the whole prompt was cached and only generation writes into it.
  * speculative decoding — each slot owns an n-gram draft proposer
    (serving/draft.py) over its prompt + generated history.
    `prepare_verify` assembles per-lane draft chains [pending, d1..dk],
    claims the blocks the chain would write, and pads to the runner's
    verify bucket; `consume_verify` accepts the longest agreeing draft
    prefix plus the one token the model produced anyway, commits
    recurrent state at the accepted length through the runner, and
    frees exactly the blocks a rejected suffix had claimed (the
    allocator returns to its pre-draft state — property-tested).
  * lifecycle + eviction — finished sequences (max_new_tokens or eos)
    are evicted: their table row is nulled, their lane freed, every
    block reference dropped, and their unclaimed budget released.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.serving.block_manager import (NULL_BLOCK, BlockAllocator,
                                         PrefixMatch)
from repro.serving.draft import make_proposer
from repro.serving.runner import ModelRunner, PrefillRow


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0          # seconds on the engine clock (open loop)
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    arrival: float
    t_admit: float
    t_first_token: float
    t_done: float
    cached_tokens: int = 0        # prompt tokens served from the prefix cache


@dataclasses.dataclass
class _Slot:
    req: Request
    table_row: np.ndarray         # (max_blocks,) int32, NULL padded
    pos: int                      # position of the next token to feed
    pending: int                  # token to feed at `pos`
    out: List[int]
    hist: List[int]               # prompt + generated (proposer input)
    t_admit: float
    t_first: float
    cached: int                   # prefix-cache hit tokens at admission
    n_blocks: int                 # bound physical blocks (row prefix)
    prompt_blocks: int            # blocks covering the prompt (floor)
    budget: int                   # reserved-but-unbound blocks remaining
    cow_block: Optional[int]      # reserved private copy for the shared
    cow_index: int = -1           # first-divergent block (lazy COW)

    def emit(self, tokens: List[int]) -> None:
        """Append generated tokens to the output AND the proposer
        history in one place — the two views must never desynchronize
        (hist == prompt + out is the proposer's input invariant)."""
        self.out.extend(tokens)
        self.hist.extend(tokens)


@dataclasses.dataclass
class _Plan:
    """A reserved admission: prompt blocks held, budget reserved, table
    row built, ready for one row of a batched prefill dispatch."""
    req: Request
    table_row: np.ndarray
    slot: int
    cached: int
    n_blocks: int
    budget: int
    cow_block: Optional[int]
    cow_index: int
    t_admit: float

    @property
    def suffix_len(self) -> int:
        return len(self.req.prompt) - min(self.cached,
                                          len(self.req.prompt) - 1)


class Scheduler:
    """Request lifecycle over a BlockAllocator and a ModelRunner."""

    def __init__(self, allocator: BlockAllocator, runner: ModelRunner, *,
                 num_slots: int, block_size: int, max_blocks_per_seq: int,
                 max_seq_len: int, prefix_cache: bool,
                 now_fn: Callable[[], float], speculate: int = 0,
                 draft: str = "ngram", ngram: int = 3):
        self.allocator = allocator
        self.runner = runner
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.max_seq_len = max_seq_len
        self.prefix_cache = prefix_cache
        self._now = now_fn
        self.speculate = max(0, speculate)
        # one proposer per lane: drafting is per-sequence state-free
        # today (n-gram lookup), but the ownership point is the seam a
        # stateful draft-model proposer will need
        self._proposers = [make_proposer(draft, ngram=ngram)
                           for _ in range(num_slots)] if speculate else []
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[_Slot]] = [None] * num_slots
        self._reserved_budget = 0     # sum of live slots' budgets
        self.completions: List[Completion] = []
        self.reset_stats()

    def reset_stats(self) -> None:
        self.prompt_tokens = 0
        self.cached_prompt_tokens = 0
        self.prefix_hit_requests = 0
        self.proposed_tokens = 0      # draft tokens sent to verify
        self.accepted_tokens = 0      # draft tokens accepted

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 (the "
                f"first token is sampled from the prefill logits)")
        if len(req.prompt) + req.max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new_tokens} exceeds "
                f"max_seq_len {self.max_seq_len}")
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _match(self, req: Request) -> PrefixMatch:
        if not self.prefix_cache:
            return PrefixMatch([], None, 0)
        return self.allocator.match_prefix(req.prompt)

    def _reserve(self, req: Request, slot: int,
                 match: PrefixMatch) -> Optional[_Plan]:
        """Share the matched prefix blocks, allocate the prompt's
        remaining blocks, reserve the generation budget, build the
        table row. Returns None (nothing held) if the pool is short."""
        P = len(req.prompt)
        bs = self.block_size
        total = -(-(P + req.max_new_tokens) // bs)
        n_prompt = -(-P // bs)
        budget = total - n_prompt
        f = len(match.full_blocks)
        # the admission gate is still conservative (the FULL extent must
        # be coverable) so an admitted request can never deadlock — but
        # only the prompt blocks are bound now; the rest stays a budget.
        # Matched blocks parked in the cached-free pool count as
        # allocatable supply in num_free, yet share() is about to revive
        # them — charge for those too, or the reserved-budget invariant
        # (num_free >= _reserved_budget, what makes _claim_blocks
        # infallible) breaks under a tight pool.
        revived = sum(1 for b in match.blocks()
                      if self.allocator.refcount(b) == 0)
        if (total - f + revived
                > self.allocator.num_free - self._reserved_budget):
            return None
        self.allocator.share(match)       # revive + hold before alloc
        fresh = self.allocator.alloc(n_prompt - f)
        if fresh is None:                 # unreachable given the gate
            self.allocator.unshare(match)
            return None
        row = np.full(self.max_blocks_per_seq, NULL_BLOCK, np.int32)
        row[:f] = match.full_blocks
        cached = f * bs + match.partial_len
        cow_block, cow_index = None, -1
        rest = fresh
        if match.partial_block is not None:
            if match.partial_len == P - f * bs:
                # whole prompt cached up to this block: keep sharing it;
                # generation's first write will trigger the lazy copy
                row[f] = match.partial_block
                cow_block, cow_index = fresh[0], f
            else:
                # prompt diverges mid-block: copy now, prefill writes it
                self.runner.copy_block(match.partial_block, fresh[0])
                self.allocator.decref(match.partial_block)
                row[f] = fresh[0]
            rest = fresh[1:]
            row[f + 1:f + 1 + len(rest)] = rest
        else:
            row[f:f + len(fresh)] = fresh
        self._reserved_budget += budget
        self.prompt_tokens += P
        self.cached_prompt_tokens += min(cached, P - 1)
        if cached > 0:
            self.prefix_hit_requests += 1
            self.allocator.touch(match.full_blocks)
        return _Plan(req=req, table_row=row, slot=slot, cached=cached,
                     n_blocks=n_prompt, budget=budget, cow_block=cow_block,
                     cow_index=cow_index, t_admit=self._now())

    def admit(self) -> None:
        """Form same-bucket groups from the queue and admit each group
        in one batched prefill dispatch, while lanes and blocks last."""
        while True:
            free = self._free_slots()
            if not free or not self._queue:
                return
            cap = min(len(free), self.runner.prefill_max_batch)
            plans: List[_Plan] = []
            bucket = None
            skipped: List[Request] = []
            while self._queue and len(plans) < cap:
                req = self._queue[0]
                match = self._match(req)  # peek: takes no references
                suf = len(req.prompt) - min(
                    match.tokens(self.block_size), len(req.prompt) - 1)
                b = self.runner.suffix_bucket(suf)
                if bucket is not None and b != bucket:
                    skipped.append(self._queue.popleft())
                    continue
                plan = self._reserve(req, free[len(plans)], match)
                if plan is None:
                    break                 # pool exhausted; retry later
                self._queue.popleft()
                plans.append(plan)
                bucket = b
            for req in reversed(skipped):
                self._queue.appendleft(req)
            if not plans:
                return
            self._dispatch(plans)

    def _dispatch(self, plans: List[_Plan]) -> None:
        rows = [PrefillRow(tokens=np.asarray(p.req.prompt, np.int32),
                           cached_len=p.cached, slot=p.slot,
                           table_row=p.table_row) for p in plans]
        first = self.runner.prefill(rows)   # blocks: TTFT covers it
        t_first = self._now()
        for p, tok in zip(plans, first):
            P = len(p.req.prompt)
            if self.prefix_cache:
                self.allocator.register_prefix(
                    p.req.prompt, [int(b) for b in p.table_row])
            self.runner.write_table(p.slot, p.table_row)
            self._slots[p.slot] = _Slot(
                req=p.req, table_row=p.table_row, pos=P, pending=int(tok),
                out=[int(tok)],
                hist=[int(t) for t in p.req.prompt] + [int(tok)],
                t_admit=p.t_admit, t_first=t_first, cached=p.cached,
                n_blocks=p.n_blocks, prompt_blocks=p.n_blocks,
                budget=p.budget, cow_block=p.cow_block,
                cow_index=p.cow_index)
            self._maybe_finish(p.slot)

    # ------------------------------------------------------------------
    # incremental block claim / release (the draft reservation)
    # ------------------------------------------------------------------

    def _claim_blocks(self, slot_id: int, last_pos: int) -> int:
        """Bind physical blocks so the table covers a write at
        `last_pos`, drawing them from the slot's reserved budget.
        Cannot fail: admission guaranteed the budget, and the global
        reserved counter kept later admissions from eating it.
        Returns the number of blocks claimed."""
        s = self._slots[slot_id]
        need = last_pos // self.block_size + 1
        claimed = 0
        while s.n_blocks < need:
            got = self.allocator.alloc(1)
            assert got is not None and s.budget > 0, \
                "block budget invariant violated"
            s.table_row[s.n_blocks] = got[0]
            s.n_blocks += 1
            s.budget -= 1
            self._reserved_budget -= 1
            claimed += 1
        if claimed:
            self.runner.write_table(slot_id, s.table_row)
        return claimed

    def _trim_blocks(self, slot_id: int, last_pos: int) -> int:
        """Release bound blocks past the last committed write at
        `last_pos` back to the allocator and return them to the slot's
        budget — the rollback of `_claim_blocks` for a rejected draft
        suffix. Never trims into the prompt. Returns #blocks freed."""
        s = self._slots[slot_id]
        keep = max(last_pos // self.block_size + 1, s.prompt_blocks)
        freed = 0
        while s.n_blocks > keep:
            s.n_blocks -= 1
            self.allocator.decref(int(s.table_row[s.n_blocks]))
            s.table_row[s.n_blocks] = NULL_BLOCK
            s.budget += 1
            self._reserved_budget += 1
            freed += 1
        if freed:
            self.runner.write_table(slot_id, s.table_row)
        return freed

    def _fire_cow(self, slot_id: int) -> None:
        """A slot about to write into a still-shared first-divergent
        block swaps in its reserved private copy first (lazy COW)."""
        s = self._slots[slot_id]
        if s.cow_block is None:
            return
        old = int(s.table_row[s.cow_index])
        self.runner.copy_block(old, s.cow_block)
        self.allocator.decref(old)
        s.table_row[s.cow_index] = s.cow_block
        self.runner.write_table(slot_id, s.table_row)
        s.cow_block = None

    # ------------------------------------------------------------------
    # decode-side lifecycle
    # ------------------------------------------------------------------

    def prepare_decode(self):
        """Assemble the plain one-token decode batch; fire pending lazy
        copy-on-writes and claim the block each lane's write needs.
        Returns (tokens, positions, active slot ids) or None when no
        lane is active."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return None
        tokens = np.zeros(self.num_slots, np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        for i in active:
            s = self._slots[i]
            self._fire_cow(i)
            self._claim_blocks(i, s.pos)
            tokens[i] = s.pending
            positions[i] = s.pos
        return tokens, positions, active

    def consume(self, active: List[int], next_tok: np.ndarray) -> None:
        """Advance each active lane with its sampled token; finish and
        evict lanes that hit max_new_tokens or eos."""
        for i in active:
            s = self._slots[i]
            s.pos += 1
            s.pending = int(next_tok[i])
            s.emit([s.pending])
            self._maybe_finish(i)

    # ------------------------------------------------------------------
    # speculative decoding: propose -> verify -> accept / rollback
    # ------------------------------------------------------------------

    def prepare_verify(self):
        """Assemble a verify batch of per-lane draft chains
        [pending, d_1 .. d_k] (k from each lane's proposer, capped so
        the chain can never emit past max_new_tokens), claim the blocks
        each chain would write, and pad to the runner's chain bucket.
        Returns (tokens (num_slots, T), positions, counts, active,
        drafts) — or None when no lane proposed anything, so the engine
        falls back to the plain decode dispatch at zero overhead."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return None
        drafts: Dict[int, List[int]] = {}
        max_chain = 1
        for i in active:
            s = self._slots[i]
            k = min(self.speculate, s.req.max_new_tokens - len(s.out) - 1)
            d = self._proposers[i].propose(s.hist, k) if k > 0 else []
            # clamp: the propose(history, k) seam must not let an
            # over-eager proposer overflow the chain bucket, emit past
            # max_new_tokens, or outrun the block budget
            drafts[i] = list(d)[:max(k, 0)]
            max_chain = max(max_chain, 1 + len(drafts[i]))
        if max_chain == 1:
            return None
        T = self.runner.chain_bucket(max_chain)
        tokens = np.zeros((self.num_slots, T), np.int32)
        positions = np.zeros(self.num_slots, np.int32)
        counts = np.zeros(self.num_slots, np.int32)
        for i in active:
            s = self._slots[i]
            chain = [s.pending] + drafts[i]
            self._fire_cow(i)
            self._claim_blocks(i, s.pos + len(chain) - 1)
            tokens[i, :len(chain)] = chain
            positions[i] = s.pos
            counts[i] = len(chain)
            self.proposed_tokens += len(drafts[i])
        return tokens, positions, counts, active, drafts

    def consume_verify(self, active: List[int], drafts: Dict[int, List[int]],
                       out_tok: np.ndarray) -> None:
        """Accept/rollback after a verify dispatch. out_tok: (num_slots,
        T) greedy tokens at every chain position. Per lane: accept the
        longest prefix of the draft that agrees with the model plus the
        one bonus token, commit recurrent state at the accepted length,
        free the blocks a rejected suffix claimed, advance, and finish
        lanes that hit max_new_tokens or eos (the emitted run is cut at
        the first eos)."""
        commit_idx = np.zeros(self.num_slots, np.int32)
        accepted: Dict[int, int] = {}
        for i in active:
            d = drafts[i]
            a = 0
            while a < len(d) and int(out_tok[i, a]) == d[a]:
                a += 1
            accepted[i] = a
            commit_idx[i] = a + 1         # chain tokens consumed
        # restore recurrent slot state at each lane's accepted length
        # BEFORE host bookkeeping (no-op for pure-attention archs)
        self.runner.commit(commit_idx)
        for i in active:
            s = self._slots[i]
            a = accepted[i]
            emitted = [int(out_tok[i, t]) for t in range(a + 1)]
            if s.req.eos_id is not None and s.req.eos_id in emitted:
                emitted = emitted[:emitted.index(s.req.eos_id) + 1]
            # accepted = drafts that actually materialized as output
            # (drafts agreeing past a truncating eos don't count)
            self.accepted_tokens += len(emitted) - 1
            s.emit(emitted)
            s.pos += a + 1
            s.pending = emitted[-1]
            # rejected suffix: free exactly the blocks it claimed
            self._trim_blocks(i, s.pos - 1)
            self._maybe_finish(i)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def _maybe_finish(self, slot_id: int) -> None:
        s = self._slots[slot_id]
        done = (len(s.out) >= s.req.max_new_tokens
                or (s.req.eos_id is not None and s.out
                    and s.out[-1] == s.req.eos_id))
        if not done:
            return
        self.completions.append(Completion(
            rid=s.req.rid, prompt_len=len(s.req.prompt),
            tokens=np.asarray(s.out, np.int32), arrival=s.req.arrival,
            t_admit=s.t_admit, t_first_token=s.t_first,
            t_done=self._now(), cached_tokens=min(s.cached,
                                                  len(s.req.prompt) - 1)))
        for b in s.table_row:
            if b != NULL_BLOCK:
                self.allocator.decref(int(b))
        if s.cow_block is not None:       # reserved but never written
            self.allocator.decref(s.cow_block)
        self._reserved_budget -= s.budget
        self.runner.clear_table(slot_id)
        self._slots[slot_id] = None
