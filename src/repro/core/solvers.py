"""Local subproblem solvers for minibatch-prox inner loops.

All solvers target the (lam + gamma [+ kappa])-strongly-convex subproblem

    f(w) = (1/n) sum_i l(w, xi_i) + <c, w> + (gamma/2)||w - a||^2
           [+ (kappa/2)||w - y||^2]

where `c` is an optional linear correction (DANE) and `a` the prox anchor.
Implemented with `jax.lax.scan` so they jit cleanly and map 1:1 onto the TPU
execution model (sequential VR updates on-device, collectives outside).

Solvers:
  - svrg_pass_wr:     one without-replacement variance-reduced pass
                      (Algorithm 1 step 2; Shamir 2016 analysis)
  - prox_svrg:        Xiao & Zhang prox-SVRG epochs (quadratic handled in the
                      proximal step, so iteration complexity depends on beta)
  - saga_linear:      SAGA with O(n) *scalar* gradient memory for linear-model
                      losses (App. E experiments use SAGA)
  - gd:               deterministic gradient descent (reference)
  - exact_quadratic:  closed-form solve for least squares (oracle)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Without-replacement variance-reduced pass (Algorithm 1, step 2)
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("per_example_grad",))
def svrg_pass_wr(per_example_grad, x0, z_anchor, mu, X, y, eta, gamma, w_prox,
                 lam=0.0, linear_c=None):
    """One pass of x_r <- x_{r-1} - eta * (g(x,xi) - g(z,xi) + mu
                                           + gamma (x - w_prox) [+ lam x + c]).

    `mu` is the full minibatch gradient at the anchor `z_anchor` (computed via
    one all-reduce by the caller). Returns the average iterate (z_k update of
    Algorithm 1 step 3) and the last iterate.
    """
    if linear_c is None:
        linear_c = jnp.zeros_like(x0)
    n = X.shape[0]

    def step(carry, xi):
        x, acc = carry
        xs, ys = xi
        g = (per_example_grad(x, xs, ys) - per_example_grad(z_anchor, xs, ys)
             + mu + lam * x + gamma * (x - w_prox) + linear_c)
        x_new = x - eta * g
        return (x_new, acc + x_new), None

    (x_last, acc), _ = jax.lax.scan(step, (x0, x0), (X, y))
    return acc / (n + 1), x_last


# ----------------------------------------------------------------------------
# Prox-SVRG (Xiao & Zhang 2014) epochs for the local DANE subproblem
# ----------------------------------------------------------------------------

def _quad_prox(v, eta, gamma, a, kappa, yv):
    """argmin_w (1/2eta)||w - v||^2 + gamma/2||w-a||^2 + kappa/2||w-yv||^2."""
    return (v + eta * (gamma * a + kappa * yv)) / (1.0 + eta * (gamma + kappa))


@partial(jax.jit, static_argnames=("per_example_grad", "epochs", "steps"))
def prox_svrg(per_example_grad, key, x0, X, y, eta, gamma, a,
              kappa=0.0, yv=None, linear_c=None, lam=0.0,
              epochs: int = 2, steps: int = 0):
    """Prox-SVRG on f(w) = mean_i l(w,xi_i) + <c,w> + lam/2|w|^2
                           + gamma/2|w-a|^2 + kappa/2|w-yv|^2.

    The smooth part handled by VR gradient steps is the loss (+ the linear
    correction); the quadratic regularizers go through the exact prox, so the
    relevant smoothness is beta (of the loss), matching Lemma 17.
    """
    n = X.shape[0]
    if yv is None:
        yv = jnp.zeros_like(x0)
    if linear_c is None:
        linear_c = jnp.zeros_like(x0)
    if steps == 0:
        steps = n

    def batch_grad(w):
        g = jax.vmap(per_example_grad, in_axes=(None, 0, 0))(w, X, y)
        return jnp.mean(g, axis=0) + lam * w + linear_c

    def epoch(carry, ek):
        x, _ = carry
        z = x
        mu = batch_grad(z)
        idx = jax.random.randint(ek, (steps,), 0, n)

        def inner(x, i):
            xs, ys = X[i], y[i]
            g = (per_example_grad(x, xs, ys) - per_example_grad(z, xs, ys)
                 + mu)
            x_new = _quad_prox(x - eta * g, eta, gamma, a, kappa, yv)
            return x_new, x_new

        x_last, xs_traj = jax.lax.scan(inner, x, idx)
        x_avg = jnp.mean(xs_traj, axis=0)
        return (x_avg, x_last), None

    keys = jax.random.split(key, epochs)
    (x_avg, _), _ = jax.lax.scan(epoch, (x0, x0), keys)
    return x_avg


# ----------------------------------------------------------------------------
# SAGA with scalar gradient memory (linear-model losses)
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("scalar_grad", "steps"))
def saga_linear(scalar_grad, key, x0, X, y, eta, gamma, a,
                kappa=0.0, yv=None, linear_c=None, lam=0.0, steps: int = 0):
    """SAGA for losses with per-example gradient  s(w.x_i, y_i) * x_i.

    Stores only the *scalars* s_i (O(n) floats, not O(nd)) — the memory model
    the paper's experiments rely on. Quadratic terms via exact prox.
    """
    n = X.shape[0]
    if yv is None:
        yv = jnp.zeros_like(x0)
    if linear_c is None:
        linear_c = jnp.zeros_like(x0)
    if steps == 0:
        steps = n

    s = jax.vmap(scalar_grad, in_axes=(None, 0, 0))(x0, X, y)  # (n,)
    g_avg = X.T @ s / n

    def step(carry, i):
        x, s, g_avg = carry
        si_new = scalar_grad(x, X[i], y[i])
        g = (si_new - s[i]) * X[i] + g_avg + lam * x + linear_c
        x_new = _quad_prox(x - eta * g, eta, gamma, a, kappa, yv)
        g_avg_new = g_avg + (si_new - s[i]) * X[i] / n
        s_new = s.at[i].set(si_new)
        return (x_new, s_new, g_avg_new), x_new

    idx = jax.random.randint(key, (steps,), 0, n)
    (x_last, _, _), xs = jax.lax.scan(step, (x0, s, g_avg), idx)
    return jnp.mean(xs, axis=0)


# ----------------------------------------------------------------------------
# Deterministic reference solvers
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("grad_fn", "iters"))
def gd(grad_fn, x0, eta, iters: int = 100):
    def step(x, _):
        return x - eta * grad_fn(x), None
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def exact_quadratic(w_prev, X, y, gamma, lam=0.0, linear_c=None,
                    kappa=0.0, yv=None):
    """Closed-form solve of the (corrected) least-squares prox subproblem."""
    if X.ndim == 3:
        X = X.reshape(-1, X.shape[-1])
        y = y.reshape(-1)
    b, d = X.shape
    if linear_c is None:
        linear_c = jnp.zeros(d, dtype=X.dtype)
    if yv is None:
        yv = jnp.zeros(d, dtype=X.dtype)
    H = X.T @ X / b + (lam + gamma + kappa) * jnp.eye(d, dtype=X.dtype)
    rhs = X.T @ y / b - linear_c + gamma * w_prev + kappa * yv
    return jnp.linalg.solve(H, rhs)
