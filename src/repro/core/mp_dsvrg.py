"""MP-DSVRG — Algorithm 1: minibatch-prox with distributed SVRG inner solver.

SPMD formulation. The per-machine program `_dsvrg_inner_spmd` is written once
against a named machine axis and executed either

  - under `jax.vmap(axis_name=...)` — exact m-machine semantics on one host
    (used by tests/benchmarks on CPU), or
  - under `jax.shard_map` on a real mesh axis (used at scale) — identical code.

Fidelity notes vs. the paper's pseudo-code:
  * Step 1 (global gradient at z_{k-1}) is `lax.pmean` over machines — one
    all-reduce round, exactly the paper's communication.
  * Step 2 prescribes that a *single* designated machine j runs the
    without-replacement VR pass. In SPMD every machine runs the pass on its
    own local batch and the designated machine's result is selected via
    mask+psum — numerically identical to machine j computing alone, at the
    cost of (algorithmically idle) duplicate compute on other machines. The
    accounting ledger counts the *algorithm's* cost model (Table 1), i.e. the
    designated machine's ops; the roofline of the TPU mapping is analysed
    separately (EXPERIMENTS.md §Roofline discusses why MP-DANE is the
    TPU-native variant).
  * Step 3 broadcast of z_k is the same psum (results replicated). We carry
    the running SVRG iterate x alongside z so the hand-off between designated
    machines is well-defined (the paper leaves the x hand-off implicit).
  * z_k is the average over the pass iterates x_0..x_{|B|} (|B|+1 terms; the
    paper's normalization 1/|B| over |B|+1 terms is treated as a typo).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import theory
from repro.core.accounting import Ledger
from repro.core.losses import Loss, least_squares

AXIS = "machines"


def _dsvrg_inner_spmd(loss: Loss, w_prev, x_init, X_loc, y_loc,
                      gamma, eta, p: int, K: int, m: int, lam: float,
                      axis: str = AXIS):
    """K inner DSVRG iterations for the prox subproblem. Per-machine program.

    X_loc: (b, d) local minibatch; splits into p batches of size b//p.
    Returns (z_K, x_last).
    """
    machine_id = lax.axis_index(axis)
    b, d = X_loc.shape
    batch = b // p
    Xb = X_loc[: p * batch].reshape(p, batch, d)
    yb = y_loc[: p * batch].reshape(p, batch)

    def local_grad(w):
        return (X_loc.T @ (X_loc @ w - y_loc)) / b + lam * w

    def inner(carry, k):
        z, x = carry
        # -- step 1: one all-reduce for the exact minibatch gradient at z --
        mu = lax.pmean(local_grad(z), axis)
        # -- step 2: designated machine j runs the VR pass on batch s --
        j = (k // p) % m
        s = k % p

        def pass_step(cx, xi):
            xv, acc = cx
            xs, ys = xi
            g = (loss.per_example_grad(xv, xs, ys)
                 - loss.per_example_grad(z, xs, ys)
                 + mu + gamma * (xv - w_prev))
            x_new = xv - eta * g
            return (x_new, acc + x_new), None

        (x_last, acc), _ = lax.scan(pass_step, (x, x), (Xb[s], yb[s]))
        z_cand = acc / (batch + 1)
        # -- step 3: select machine j's result and broadcast (one psum) --
        mask = (machine_id == j).astype(z.dtype)
        z_new = lax.psum(mask * z_cand, axis)
        x_new = lax.psum(mask * x_last, axis)
        return (z_new, x_new), None

    (z, x), _ = lax.scan(inner, (w_prev, x_init), jnp.arange(K))
    return z, x


@dataclasses.dataclass
class MPDSVRGResult:
    w_avg: jnp.ndarray
    w_last: jnp.ndarray
    iterates: jnp.ndarray
    plan: theory.MPDSVRGPlan
    ledger: Ledger


def run_mp_dsvrg(stream, spec: theory.ProblemSpec, m: int, b: int, T: int,
                 *, K: Optional[int] = None, p: Optional[int] = None,
                 gamma: Optional[float] = None, eta_scale: float = 0.3,
                 lam: float = 0.0, seed: int = 0,
                 loss: Optional[Loss] = None) -> MPDSVRGResult:
    """Run Algorithm 1 for T outer iterations, m machines, b samples/machine.

    Parameters default to the Theorem-10 plan computed from (spec, n=bmT).
    """
    n = b * m * T
    plan = theory.mp_dsvrg_plan(spec, n, m, b)
    K = K if K is not None else plan.K
    p = p if p is not None else plan.p
    p = max(1, min(p, b))
    gamma = gamma if gamma is not None else plan.gamma
    plan = dataclasses.replace(plan, T=T, K=K, p=p, gamma=gamma,
                               batch=b // p)
    eta = eta_scale / (spec.beta + gamma + lam)
    loss = loss or least_squares()

    ledger = Ledger()
    ledger.hold(b)

    inner = partial(_dsvrg_inner_spmd, loss, gamma=gamma, eta=eta,
                    p=p, K=K, m=m, lam=lam)

    @jax.jit
    def outer_step(w_prev, Xm, ym):
        spmd = jax.vmap(lambda X, y: inner(w_prev, w_prev, X, y),
                        axis_name=AXIS)
        z, _ = spmd(Xm, ym)
        return z[0]  # replicated across machines

    key = jax.random.PRNGKey(seed)
    w = jnp.zeros(stream.dim)
    iterates = []
    for _ in range(T):
        key, kd = jax.random.split(key)
        Xm, ym = stream.sample_distributed(kd, m, b)
        w = outer_step(w, Xm, ym)
        iterates.append(w)
        # accounting per Algorithm 1: K inner iters x 2 rounds (grad + bcast)
        ledger.communicate(vectors=2 * K, rounds=2 * K)
        # per machine: local gradient O(b) per inner iter; the designated
        # machine additionally runs b/p stochastic updates
        ledger.compute(K * (b + b // p))

    iterates = jnp.stack(iterates)
    return MPDSVRGResult(w_avg=iterates.mean(0), w_last=w,
                         iterates=iterates, plan=plan, ledger=ledger)
