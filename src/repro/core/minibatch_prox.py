"""Minibatch-prox (Section 3): exact and inexact outer loops.

This is the analysis-level algorithm: at step t draw a fresh minibatch I_t of
b samples and set

    w_t ~= argmin_w  phi_{I_t}(w) + (gamma_t/2) ||w - w_{t-1}||^2 .

`run_minibatch_prox` supports:
  - exact subproblem solves (closed-form least squares oracle)      [Thm 4/5]
  - inexact solves through any solver meeting the eta_t schedule    [Thm 7/8]
  - weakly convex (constant gamma) and strongly convex (gamma_t = lam(t-1)/2)
  - the averaged outputs of the theorems (uniform / t-weighted)

Distributed execution lives in mp_dsvrg.py / mp_dane.py; this module is the
single-sequence form used to validate the statistical claims.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import prox, solvers, theory
from repro.core.accounting import Ledger


@dataclasses.dataclass
class ProxResult:
    w_avg: jnp.ndarray          # theorem-prescribed averaged predictor
    w_last: jnp.ndarray
    iterates: jnp.ndarray       # (T, d)
    gammas: jnp.ndarray         # (T,)
    ledger: Ledger


def run_minibatch_prox(
    stream,
    spec: theory.ProblemSpec,
    b: int,
    T: int,
    *,
    solver: str = "exact",
    strongly_convex: bool = False,
    lam: float = 0.0,
    gamma_override: Optional[float] = None,
    inner_steps: int = 0,
    inner_epochs: int = 2,
    seed: int = 0,
    radius: float = float("inf"),
    w0: Optional[jnp.ndarray] = None,
) -> ProxResult:
    """Run T iterations of minibatch-prox with minibatch size b.

    solver: 'exact' | 'gd' | 'prox_svrg' | 'saga'
    For strongly_convex=True uses gamma_t = lam (t-1)/2 and t-weighted average
    (Thm 5/8); otherwise constant gamma from Thm 4/7 and uniform average.
    """
    d = stream.dim
    w = jnp.zeros(d) if w0 is None else w0
    key = jax.random.PRNGKey(seed)
    ledger = Ledger()
    ledger.hold(b)  # each machine holds its current minibatch

    iterates = []
    gammas = []
    from repro.core.losses import (least_squares, ridge_least_squares)
    loss = ridge_least_squares(lam) if lam > 0 else least_squares()

    for t in range(1, T + 1):
        key, kd, ks = jax.random.split(key, 3)
        X, y = stream.sample(kd, b)
        if strongly_convex:
            gamma_t = theory.gamma_strongly_convex(spec, t)
            gamma_t = max(gamma_t, 1e-8)  # t=1 => pure ERM on the minibatch
        else:
            gamma_t = (gamma_override if gamma_override is not None
                       else theory.gamma_weakly_convex(spec, b, T))
        gammas.append(gamma_t)

        if solver == "exact":
            w_new = prox.exact_lsq_prox(w, X, y, gamma_t, lam=lam)
            ledger.compute(b)  # forming X^T X / X^T y: O(b) vector ops
        elif solver == "gd":
            def grad_fn(wv, X=X, y=y, g=gamma_t, a=w):
                return prox.prox_subproblem_grad(wv, a, X, y, g, lam=lam)
            eta = 1.0 / (spec.beta + lam + gamma_t)
            iters = inner_steps or 64
            w_new = solvers.gd(grad_fn, w, eta, iters=iters)
            ledger.compute(iters * b)
        elif solver == "prox_svrg":
            eta = 0.1 / spec.beta
            w_new = solvers.prox_svrg(
                loss.per_example_grad, ks, w, X, y, eta, gamma_t, w,
                lam=0.0, epochs=inner_epochs, steps=inner_steps or b)
            ledger.compute(inner_epochs * (b + (inner_steps or b)))
        elif solver == "saga":
            def scalar_grad(wv, xv, yv):
                return jnp.dot(wv, xv) - yv
            eta = 0.3 / spec.beta
            w_new = solvers.saga_linear(
                scalar_grad, ks, w, X, y, eta, gamma_t, w,
                lam=lam, steps=inner_steps or b)
            ledger.compute(b + (inner_steps or b))
        else:
            raise ValueError(f"unknown solver {solver!r}")

        if radius != float("inf"):
            w_new = prox.project_l2_ball(w_new, radius)
        w = w_new
        iterates.append(w)

    iterates = jnp.stack(iterates)
    if strongly_convex:
        t_idx = jnp.arange(1, T + 1, dtype=iterates.dtype)
        w_avg = (t_idx[:, None] * iterates).sum(0) * 2.0 / (T * (T + 1))
    else:
        w_avg = iterates.mean(0)
    return ProxResult(w_avg=w_avg, w_last=w, iterates=iterates,
                      gammas=jnp.asarray(gammas), ledger=ledger)
