"""Baselines the paper compares against (Table 1 / Figure 2 / Figure 3).

  - minibatch_sgd:        distributed minibatch SGD (Dekel et al. 2012)
  - acc_minibatch_sgd:    accelerated minibatch SGD, AC-SA form
                          (Cotter et al. 2011 / Ghadimi & Lan)
  - single_sgd:           single-machine SGD (statistical reference)
  - dsvrg_erm:            DSVRG on the regularized ERM objective (eq. 2)
                          (Lee et al. 2015; the paper's Section 2)
  - emso:                 one-shot-averaged local prox solves (Li et al. 2014)
                          = MP-DANE with correction disabled, K=R=1

All distributed baselines use the same vmap/shard_map 'machines'-axis SPMD
formulation as mp_dsvrg/mp_dane, and thread the same accounting Ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import prox, theory
from repro.core.accounting import Ledger
from repro.core.losses import least_squares
from repro.core.mp_dane import run_mp_dane
from repro.core.mp_dsvrg import _dsvrg_inner_spmd

AXIS = "machines"


@dataclasses.dataclass
class BaselineResult:
    w_avg: jnp.ndarray
    w_last: jnp.ndarray
    ledger: Ledger


# ----------------------------------------------------------------------------
# Minibatch SGD: w_t = P( w_{t-1} - (1/gamma_t) grad phi_{I_t}(w_{t-1}) )
# ----------------------------------------------------------------------------

def run_minibatch_sgd(stream, spec: theory.ProblemSpec, m: int, b: int,
                      T: int, *, gamma: Optional[float] = None,
                      radius: float = float("inf"), seed: int = 0,
                      loss=None) -> BaselineResult:
    """Prop. 13 tuning: gamma = beta + sqrt(4T/(bm)) L / B (bm = total batch)."""
    bm = b * m
    if gamma is None:
        gamma = spec.beta + (4.0 * T / bm) ** 0.5 * spec.L / spec.B
    ledger = Ledger()
    ledger.hold(1)

    @jax.jit
    def step(w, Xm, ym):
        def local(X, y):
            if loss is None:
                g = X.T @ (X @ w - y) / X.shape[0]
            else:
                g = jax.vmap(loss.per_example_grad,
                             (None, 0, 0))(w, X, y).mean(0)
            return lax.pmean(g, AXIS)
        g = jax.vmap(local, axis_name=AXIS)(Xm, ym)[0]
        w_new = w - g / gamma
        if radius != float("inf"):
            w_new = prox.project_l2_ball(w_new, radius)
        return w_new

    key = jax.random.PRNGKey(seed)
    w = jnp.zeros(stream.dim)
    acc = jnp.zeros(stream.dim)
    for _ in range(T):
        key, kd = jax.random.split(key)
        Xm, ym = stream.sample_distributed(kd, m, b)
        w = step(w, Xm, ym)
        acc = acc + w
        ledger.communicate(vectors=1, rounds=1)
        ledger.compute(b)
    return BaselineResult(w_avg=acc / T, w_last=w, ledger=ledger)


# ----------------------------------------------------------------------------
# Accelerated minibatch SGD (AC-SA two-sequence scheme)
# ----------------------------------------------------------------------------

def run_acc_minibatch_sgd(stream, spec: theory.ProblemSpec, m: int, b: int,
                          T: int, *, radius: float = float("inf"),
                          seed: int = 0, step_scale: float = 1.0
                          ) -> BaselineResult:
    """AC-SA (Ghadimi & Lan): alpha_t = 2/(t+1),
    lambda_t = t/2 * min(1/(2 beta), B sqrt(bm) / (2 L T^{3/2}))."""
    bm = b * m
    base = min(1.0 / (2.0 * spec.beta),
               step_scale * spec.B * (bm ** 0.5) / (2.0 * spec.L * T ** 1.5))
    ledger = Ledger()
    ledger.hold(2)

    @jax.jit
    def step(carry, Xm, ym, t):
        w, w_ag = carry
        alpha = 2.0 / (t + 1.0)
        lam_t = 0.5 * t * base
        w_md = (1 - alpha) * w_ag + alpha * w

        def local(X, y):
            g = X.T @ (X @ w_md - y) / X.shape[0]
            return lax.pmean(g, AXIS)
        g = jax.vmap(local, axis_name=AXIS)(Xm, ym)[0]
        w_new = w - lam_t * g
        if radius != float("inf"):
            w_new = prox.project_l2_ball(w_new, radius)
        w_ag_new = (1 - alpha) * w_ag + alpha * w_new
        return (w_new, w_ag_new)

    key = jax.random.PRNGKey(seed)
    w = jnp.zeros(stream.dim)
    w_ag = jnp.zeros(stream.dim)
    for t in range(1, T + 1):
        key, kd = jax.random.split(key)
        Xm, ym = stream.sample_distributed(kd, m, b)
        w, w_ag = step((w, w_ag), Xm, ym, float(t))
        ledger.communicate(vectors=1, rounds=1)
        ledger.compute(b)
    return BaselineResult(w_avg=w_ag, w_last=w, ledger=ledger)


# ----------------------------------------------------------------------------
# Single-machine SGD (sample-optimal reference)
# ----------------------------------------------------------------------------

def run_single_sgd(stream, spec: theory.ProblemSpec, n: int, *,
                   radius: float = float("inf"), seed: int = 0
                   ) -> BaselineResult:
    key = jax.random.PRNGKey(seed)
    X, y = stream.sample(key, n)
    etas = spec.B / (spec.L * jnp.sqrt(jnp.arange(1, n + 1, dtype=jnp.float32)))

    @jax.jit
    def run(w0):
        def step(carry, xi):
            w, acc = carry
            xv, yv, eta = xi
            g = (jnp.dot(w, xv) - yv) * xv
            w_new = w - eta * g
            if radius != float("inf"):
                w_new = prox.project_l2_ball(w_new, radius)
            return (w_new, acc + w_new), None
        (w, acc), _ = lax.scan(step, (w0, jnp.zeros_like(w0)), (X, y, etas))
        return acc / n, w

    w_avg, w_last = run(jnp.zeros(stream.dim))
    ledger = Ledger()
    ledger.compute(n)
    ledger.hold(1)
    return BaselineResult(w_avg=w_avg, w_last=w_last, ledger=ledger)


# ----------------------------------------------------------------------------
# DSVRG on regularized ERM (Section 2): fixed dataset, nu = L/(B sqrt(n))
# ----------------------------------------------------------------------------

def run_dsvrg_erm(stream, spec: theory.ProblemSpec, m: int, n: int, *,
                  K: Optional[int] = None, eta_scale: float = 0.3,
                  seed: int = 0) -> BaselineResult:
    """Solves min_w phi_S(w) + nu/2 ||w||^2 on a stored dataset of n samples."""
    nu = spec.L / (spec.B * n ** 0.5)
    b_loc = n // m
    K = K if K is not None else max(1, int(jnp.log(jnp.asarray(float(n)))))
    key = jax.random.PRNGKey(seed)
    Xm, ym = stream.sample_distributed(key, m, b_loc)
    gamma_eff = nu  # ridge acts like the prox term with anchor 0
    eta = eta_scale / (spec.beta + gamma_eff)
    loss = least_squares()

    @jax.jit
    def solve(w0):
        inner = jax.vmap(
            lambda X, y: _dsvrg_inner_spmd(
                loss, jnp.zeros_like(w0), w0, X, y, gamma_eff, eta,
                p=1, K=K, m=m, lam=0.0),
            axis_name=AXIS)
        z, _ = inner(Xm, ym)
        return z[0]

    w = solve(jnp.zeros(stream.dim))
    ledger = Ledger()
    ledger.hold(b_loc)                      # must store the local shard
    ledger.communicate(vectors=2 * K, rounds=2 * K)
    ledger.compute(K * (b_loc + b_loc))
    return BaselineResult(w_avg=w, w_last=w, ledger=ledger)


# ----------------------------------------------------------------------------
# EMSO: one-shot averaging of local exact prox solves (Li et al. 2014)
# ----------------------------------------------------------------------------

def run_emso(stream, spec: theory.ProblemSpec, m: int, b: int, T: int,
             *, gamma: Optional[float] = None, seed: int = 0):
    return run_mp_dane(stream, spec, m, b, T, K=1, R=1, kappa=0.0,
                       gamma=gamma, local_solver="exact", correction=False,
                       seed=seed)
