"""The paper's theory, in code.

Every schedule/parameter the theorems prescribe lives here so that algorithms,
tests and benchmarks share one source of truth:

  - sample complexity n(eps) = O(L^2 B^2 / eps^2)
  - Thm 4  (exact, weakly convex):    gamma = sqrt(8T/b) * L / ||w0 - w*||
  - Thm 5  (exact, strongly convex):  gamma_t = lam (t-1) / 2
  - Thm 7/8 inexactness schedules eta_t
  - Thm 10 (MP-DSVRG): T, gamma, p_i, K
  - Thm 14/16 (MP-DANE): b*, kappa, R, K, theta
  - Table 1 / Table 2 resource model (communication / computation / memory)
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """Constants of the stochastic convex problem."""

    L: float          # Lipschitz constant of the instantaneous loss
    beta: float       # smoothness
    B: float          # competitor norm bound / ||w0 - w*||
    lam: float = 0.0  # strong convexity (0 = weakly convex)
    dim: int = 1


def n_eps(spec: ProblemSpec, eps: float) -> int:
    """Min-max optimal sample complexity n(eps) = L^2 B^2 / eps^2."""
    return max(1, int(math.ceil(spec.L**2 * spec.B**2 / eps**2)))


# ----------------------------------------------------------------------------
# Minibatch-prox schedules (Section 3)
# ----------------------------------------------------------------------------

def gamma_weakly_convex(spec: ProblemSpec, b: int, T: int) -> float:
    """Thm 4/7: gamma = sqrt(8 T / b) * L / ||w0 - w*||  (constant over t)."""
    return math.sqrt(8.0 * T / b) * spec.L / spec.B


def gamma_strongly_convex(spec: ProblemSpec, t: int) -> float:
    """Thm 5/8: gamma_t = lam (t - 1) / 2 (t is 1-indexed)."""
    return spec.lam * (t - 1) / 2.0


def eta_schedule_weakly_convex(spec: ProblemSpec, b: int, T: int, t: int,
                               c1: float = 1e-4, c2: float = 1e-4,
                               delta: float = 0.5) -> float:
    """Thm 7 inexactness budget for iteration t (1-indexed)."""
    ratio = T / b
    return (min(c1 * ratio**0.5, c2 * ratio**1.5)
            * spec.L * spec.B / t ** (2 + 2 * delta))


def eta_schedule_strongly_convex(spec: ProblemSpec, b: int, T: int, t: int,
                                 c1: float = 1e-4, c2: float = 1e-4,
                                 delta: float = 0.5) -> float:
    """Thm 8 inexactness budget for iteration t (1-indexed)."""
    ratio = T / b
    return (min(c1 * ratio, c2 * ratio**2)
            * spec.L**2 / (t ** (3 + 2 * delta) * spec.lam))


def rate_bound_weakly_convex(spec: ProblemSpec, b: int, T: int,
                             exact: bool = True) -> float:
    """Thm 4: sqrt(8) L B / sqrt(bT); Thm 7 (c1=c2=1e-4, delta=.5): sqrt(10)."""
    c = math.sqrt(8.0) if exact else math.sqrt(10.0)
    return c * spec.L * spec.B / math.sqrt(b * T)


def rate_bound_strongly_convex(spec: ProblemSpec, b: int, T: int) -> float:
    """Thm 5: 16 L^2 / (lam b (T+1))."""
    return 16.0 * spec.L**2 / (spec.lam * b * (T + 1))


# ----------------------------------------------------------------------------
# MP-DSVRG parameters (Theorem 10)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MPDSVRGPlan:
    T: int            # outer minibatch-prox iterations
    gamma: float      # prox regularization
    K: int            # DSVRG inner iterations per outer step
    p: int            # local batches per machine (memory b, batch size b/p)
    batch: int        # b / p  (stochastic-pass length per inner iteration)

    @property
    def comm_rounds(self) -> int:
        # two communications per inner iteration (gradient avg + broadcast)
        return 2 * self.K * self.T

    def memory_vectors(self, b: int) -> int:
        return b  # each machine holds its current minibatch only

    def computation_vector_ops(self, b: int) -> int:
        # per machine: local gradient (b ops) + 1/m-th of the stochastic pass
        return self.K * self.T * (b + self.batch)


def mp_dsvrg_plan(spec: ProblemSpec, n: int, m: int, b: int,
                  k_multiplier: float = 1.0) -> MPDSVRGPlan:
    """Thm 10: T = n/(bm), gamma = sqrt(8n) L/(bmB), p_i = O(sqrt(n) L/(beta m B)),
    K = O(log n)."""
    T = max(1, n // (b * m))
    gamma = math.sqrt(8.0 * n) * spec.L / (b * m * spec.B)
    # condition number of f_t: (beta + gamma)/gamma; pick batch >= cond number
    cond = (spec.beta + gamma) / gamma
    batch = min(b, max(1, int(math.ceil(cond))))
    p = max(1, b // batch)
    K = max(1, int(math.ceil(k_multiplier * math.log(max(n, 2)))))
    return MPDSVRGPlan(T=T, gamma=gamma, K=K, p=p, batch=b // p)


# ----------------------------------------------------------------------------
# MP-DANE parameters (Theorems 14 / 16)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MPDANEPlan:
    T: int
    gamma: float
    kappa: float      # catalyst regularization (0 below b*)
    R: int            # AIDE (catalyst) rounds
    K: int            # inexact-DANE iterations per round
    theta: float      # local solve accuracy
    b_star: int

    @property
    def comm_rounds(self) -> int:
        # two communications per DANE iteration (gradient avg + solution avg)
        return 2 * self.K * self.R * self.T


def b_star(spec: ProblemSpec, n: int, m: int, d: int) -> int:
    """Critical minibatch size b* = n L^2 / (32 m^2 beta^2 B^2 log(md))."""
    denom = 32.0 * m**2 * spec.beta**2 * spec.B**2 * math.log(max(m * d, 3))
    return max(1, int(n * spec.L**2 / denom))


def mp_dane_plan(spec: ProblemSpec, n: int, m: int, b: int, d: int,
                 k_multiplier: float = 1.0) -> MPDANEPlan:
    T = max(1, n // (b * m))
    gamma = math.sqrt(8.0 * n) * spec.L / (b * m * spec.B)
    bs = b_star(spec, n, m, d)
    if b <= bs:
        kappa, R = 0.0, 1
    else:
        kappa = max(0.0,
                    16.0 * spec.beta * math.sqrt(math.log(max(d * m, 3)) / b)
                    - gamma)
        R = max(1, int(math.ceil(
            math.sqrt((gamma + kappa) / gamma) * math.log(max(n, 2)))))
    K = max(1, int(math.ceil(k_multiplier * math.log(max(n, 2)))))
    return MPDANEPlan(T=T, gamma=gamma, kappa=kappa, R=R, K=K,
                      theta=1.0 / 6.0, b_star=bs)


# ----------------------------------------------------------------------------
# Table 1 / Table 2 resource model (per machine, ignoring constants/logs)
# ----------------------------------------------------------------------------

def table1_resources(method: str, spec: ProblemSpec, n: int, m: int,
                     b: int | None = None) -> dict:
    """Asymptotic resources from the paper's Table 1 (units: vectors)."""
    B = spec.B
    if method == "ideal":
        return dict(samples=n, communication=1, computation=n / m, memory=1)
    if method == "accelerated_gd":
        return dict(samples=n, communication=B**0.5 * n**0.25,
                    computation=B**0.5 * n**1.25 / m, memory=n / m)
    if method == "acc_minibatch_sgd":
        return dict(samples=n, communication=B**0.5 * n**0.25,
                    computation=n / m, memory=1)
    if method == "dane":
        return dict(samples=n, communication=B**2 * m,
                    computation=B**2 * n, memory=n / m)
    if method in ("disco", "aide"):
        return dict(samples=n, communication=B**0.5 * m**0.25,
                    computation=B**0.5 * n / m**0.75, memory=n / m)
    if method == "dsvrg":
        return dict(samples=n, communication=1, computation=n / m, memory=n / m)
    if method == "mp_dsvrg":
        assert b is not None
        return dict(samples=n, communication=n / (m * b),
                    computation=n / m, memory=b)
    if method == "mp_dane":
        assert b is not None
        bs = b_star(spec, n, m, spec.dim)
        if b <= bs:
            return dict(samples=n, communication=n / (m * b),
                        computation=n / m, memory=b)
        return dict(samples=n,
                    communication=B**0.5 * n**0.75 / (m**0.5 * b**0.75),
                    computation=B**0.5 * n**0.75 * b**0.25 / m**0.5, memory=b)
    raise ValueError(f"unknown method {method!r}")
