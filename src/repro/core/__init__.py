"""repro.core — the paper's contribution: minibatch-prox distributed
stochastic optimization (MP-DSVRG / MP-DANE) and the baselines it is
analyzed against."""
from repro.core import losses, prox, solvers, theory  # noqa: F401
from repro.core.accounting import Ledger  # noqa: F401
from repro.core.minibatch_prox import run_minibatch_prox  # noqa: F401
from repro.core.mp_dane import run_mp_dane  # noqa: F401
from repro.core.mp_dsvrg import run_mp_dsvrg  # noqa: F401
