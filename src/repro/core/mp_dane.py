"""MP-DANE — Algorithm 2: minibatch-prox + AIDE(catalyst) + inexact DANE.

Three nested loops: t (minibatch-prox outer), r (AIDE catalyst), k (DANE).
Each DANE iteration: one all-reduce for the global minibatch gradient at
z_{k-1}, a *local* corrected subproblem solve on every machine (this is the
all-machines-busy variant — the TPU-native form of the paper's technique),
and one all-reduce to average the local solutions (eq. 34).

The local subproblem (eq. 33):

  z_k^(i) ~= argmin_z  phi_{I^(i)}(z) + <grad phi_{I_t}(z_{k-1})
                        - grad phi_{I^(i)}(z_{k-1}), z>
                        + gamma/2 ||z - w_{t-1}||^2 + kappa/2 ||z - y_{r-1}||^2

solved to theta-accuracy by 'exact' (closed-form quadratic), 'saga' or
'prox_svrg' (one pass over local data — App. E setup).

EMSO (Li et al. 2014) = this algorithm with the gradient correction removed,
K=1, R=1, kappa=0 — exposed via `correction=False` for the baseline table.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import solvers, theory
from repro.core.accounting import Ledger
from repro.core.losses import Loss, least_squares

AXIS = "machines"


def _dane_round_spmd(loss: Loss, z_prev, X_loc, y_loc, w_anchor, y_cat,
                     gamma, kappa, lam, local_solver: str, key,
                     eta_scale: float, correction: bool, axis: str = AXIS):
    """One inexact-DANE iteration (steps 1-3 of the inner loop)."""
    b = X_loc.shape[0]

    def local_grad(w):
        if loss.name.startswith("least_squares"):
            return (X_loc.T @ (X_loc @ w - y_loc)) / b + lam * w
        g = jax.vmap(loss.per_example_grad, (None, 0, 0))(w, X_loc, y_loc)
        return g.mean(0) + lam * w

    g_loc = local_grad(z_prev)
    g_glob = lax.pmean(g_loc, axis)                    # round 1: gradient avg
    c = (g_glob - g_loc) if correction else jnp.zeros_like(g_glob)

    if local_solver == "exact":
        # closed form is least-squares-only; other losses use an iterative
        # local solver (saga / prox_svrg)
        z_i = solvers.exact_quadratic(w_anchor, X_loc, y_loc, gamma, lam=lam,
                                      linear_c=c, kappa=kappa, yv=y_cat)
    elif local_solver == "saga":
        def scalar_grad(wv, xv, yv):
            return jnp.dot(wv, xv) - yv
        z_i = solvers.saga_linear(scalar_grad, key, z_prev, X_loc, y_loc,
                                  eta_scale, gamma, w_anchor, kappa=kappa,
                                  yv=y_cat, linear_c=c, lam=lam)
    elif local_solver == "prox_svrg":
        z_i = solvers.prox_svrg(loss.per_example_grad, key, z_prev,
                                X_loc, y_loc, eta_scale, gamma, w_anchor,
                                kappa=kappa, yv=y_cat, linear_c=c, lam=lam,
                                epochs=1)
    else:
        raise ValueError(local_solver)

    return lax.pmean(z_i, axis)                        # round 2: solution avg


@dataclasses.dataclass
class MPDANEResult:
    w_avg: jnp.ndarray
    w_last: jnp.ndarray
    iterates: jnp.ndarray
    plan: theory.MPDANEPlan
    ledger: Ledger


def run_mp_dane(stream, spec: theory.ProblemSpec, m: int, b: int, T: int,
                *, K: Optional[int] = None, R: Optional[int] = None,
                kappa: Optional[float] = None, gamma: Optional[float] = None,
                local_solver: str = "exact", correction: bool = True,
                eta_scale: float = 0.3, lam: float = 0.0, seed: int = 0,
                loss: Optional[Loss] = None) -> MPDANEResult:
    """Run Algorithm 2. Defaults follow Theorems 14/16 given n = bmT."""
    n = b * m * T
    plan = theory.mp_dane_plan(spec, n, m, b, stream.dim)
    K = K if K is not None else plan.K
    R = R if R is not None else plan.R
    kappa = kappa if kappa is not None else plan.kappa
    gamma = gamma if gamma is not None else plan.gamma
    plan = dataclasses.replace(plan, T=T, K=K, R=R, kappa=kappa, gamma=gamma)
    loss = loss or least_squares()
    eta = eta_scale / (spec.beta + gamma + kappa + lam)

    ledger = Ledger()
    ledger.hold(b)

    @jax.jit
    def outer_step(w_prev, Xm, ym, key):
        def per_machine(X_loc, y_loc):
            # --- AIDE catalyst loop (eq. 35-36); R=1,kappa=0 => plain DANE ---
            def aide_round(carry, rk):
                x_prev, y_cat, alpha_prev = carry

                def dane_iter(z, kk):
                    z_new = _dane_round_spmd(
                        loss, z, X_loc, y_loc, w_prev, y_cat, gamma, kappa,
                        lam, local_solver, kk, eta, correction)
                    return z_new, None

                kkeys = jax.random.split(rk, K)
                x_r, _ = lax.scan(dane_iter, y_cat, kkeys)
                # alpha_r^2 = (1-alpha_r) alpha_{r-1}^2 + q alpha_r,
                #   q = gamma/(gamma+kappa)
                q = gamma / (gamma + kappa + 1e-30)
                a2 = alpha_prev**2
                disc = (q - a2) ** 2 + 4.0 * a2
                alpha = 0.5 * ((q - a2) + jnp.sqrt(disc))
                beta_mom = alpha_prev * (1 - alpha_prev) / (alpha_prev**2
                                                            + alpha)
                y_new = x_r + beta_mom * (x_r - x_prev)
                return (x_r, y_new, alpha), None

            alpha0 = jnp.sqrt(gamma / (gamma + kappa + 1e-30))
            rkeys = jax.random.split(key, R)
            (x_R, _, _), _ = lax.scan(aide_round, (w_prev, w_prev, alpha0),
                                      rkeys)
            return x_R

        spmd = jax.vmap(per_machine, axis_name=AXIS)
        out = spmd(Xm, ym)
        return out[0]

    key = jax.random.PRNGKey(seed)
    w = jnp.zeros(stream.dim)
    iterates = []
    for _ in range(T):
        key, kd, ks = jax.random.split(key, 3)
        Xm, ym = stream.sample_distributed(kd, m, b)
        w = outer_step(w, Xm, ym, ks)
        iterates.append(w)
        rounds = 2 * K * R if correction else 1
        ledger.communicate(vectors=rounds, rounds=rounds)
        ledger.compute(K * R * 2 * b)  # local grad + ~one pass per DANE iter

    iterates = jnp.stack(iterates)
    return MPDANEResult(w_avg=iterates.mean(0), w_last=w, iterates=iterates,
                        plan=plan, ledger=ledger)
