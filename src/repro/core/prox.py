"""Proximal operators for minibatch-prox.

The minibatch-prox iterate (paper eq. (3)) is

    w_t = argmin_{w in Omega}  phi_{I_t}(w) + (gamma_t / 2) ||w - w_{t-1}||^2 .

For least squares this subproblem is a d x d linear solve (the "exact" oracle
used by Theorems 4/5 and the correctness oracles of every inexact solver):

    (X^T X / b + (lam + gamma) I) w = X^T y / b + gamma w_prev   [+ ridge]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exact_lsq_prox(w_prev, X, y, gamma: float, lam: float = 0.0):
    """Closed-form minimizer of the least-squares minibatch-prox subproblem.

    Supports X of shape (b, d) or stacked machines (m, b, d) — the stacked form
    solves the *union* minibatch subproblem (eq. 12) exactly.
    """
    if X.ndim == 3:
        m, b, d = X.shape
        X = X.reshape(m * b, d)
        y = y.reshape(m * b)
    b, d = X.shape
    H = X.T @ X / b + (lam + gamma) * jnp.eye(d, dtype=X.dtype)
    rhs = X.T @ y / b + gamma * w_prev
    return jnp.linalg.solve(H, rhs)


def prox_subproblem_value(w, w_prev, X, y, gamma: float, lam: float = 0.0):
    """f_t(w) = phi_{I_t}(w) + gamma/2 ||w - w_prev||^2 (least squares)."""
    if X.ndim == 3:
        X = X.reshape(-1, X.shape[-1])
        y = y.reshape(-1)
    r = X @ w - y
    reg = 0.5 * lam * jnp.dot(w, w)
    return 0.5 * jnp.mean(r * r) + reg + 0.5 * gamma * jnp.sum((w - w_prev) ** 2)


def prox_subproblem_grad(w, w_prev, X, y, gamma: float, lam: float = 0.0):
    if X.ndim == 3:
        X = X.reshape(-1, X.shape[-1])
        y = y.reshape(-1)
    n = X.shape[0]
    return X.T @ (X @ w - y) / n + lam * w + gamma * (w - w_prev)


def project_l2_ball(w, radius: float):
    """P_Omega for Omega = {w : ||w|| <= radius}. radius=inf => identity."""
    norm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, radius / jnp.maximum(norm, 1e-30))
    return w * scale


def sgd_equivalence_residual(w_t, w_prev, X, y, gamma: float, lam: float = 0.0):
    """Residual of the implicit-gradient characterization (paper eq. (5)):

        w_t = w_{t-1} - (1/gamma) grad phi_{I_t}(w_t)        (unconstrained)

    Zero iff w_t is the exact prox point. Used by property tests.
    """
    if X.ndim == 3:
        X = X.reshape(-1, X.shape[-1])
        y = y.reshape(-1)
    n = X.shape[0]
    g = X.T @ (X @ w_t - y) / n + lam * w_t
    return w_t - (w_prev - g / gamma)
