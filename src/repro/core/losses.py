"""Convex instantaneous losses for distributed stochastic optimization.

The paper's analysis is for least squares ``l(w, xi) = 0.5 (w^T x - y)^2``
(optionally ridge-regularized to make it strongly convex); the algorithms apply
to any convex loss, so we also provide logistic loss for the App. E experiments.

Every loss exposes:
  value(w, X, y)      mean loss over the batch           (phi_I)
  grad(w, X, y)       mean gradient over the batch       (nabla phi_I)
  per_example_grad    gradient of one example            (for SVRG/SAGA inner loops)
  constants(X, ...)   (L, beta, lam) Lipschitz / smoothness / strong-convexity
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    """A convex instantaneous loss phi(w; x, y) with known constants."""

    name: str
    value_fn: callable
    grad_fn: callable
    lam: float = 0.0  # strong convexity of the *instantaneous* loss

    def value(self, w, X, y):
        """Mean loss over a batch. X: (n, d), y: (n,)."""
        return jnp.mean(jax.vmap(self.value_fn, in_axes=(None, 0, 0))(w, X, y))

    def grad(self, w, X, y):
        """Mean gradient over a batch (one vector op per example)."""
        return jnp.mean(
            jax.vmap(self.grad_fn, in_axes=(None, 0, 0))(w, X, y), axis=0
        )

    def per_example_grad(self, w, x, y):
        return self.grad_fn(w, x, y)


# --------------------------------------------------------------------------
# Least squares:  l(w, (x,y)) = 0.5 (w.x - y)^2
# --------------------------------------------------------------------------

def _lsq_value(w, x, y):
    r = jnp.dot(w, x) - y
    return 0.5 * r * r


def _lsq_grad(w, x, y):
    return (jnp.dot(w, x) - y) * x


def least_squares() -> Loss:
    return Loss("least_squares", _lsq_value, _lsq_grad, lam=0.0)


# --------------------------------------------------------------------------
# Ridge-regularized least squares: strongly convex instantaneous loss
#   l(w, xi) = 0.5 (w.x - y)^2 + lam/2 ||w||^2
# --------------------------------------------------------------------------

def ridge_least_squares(lam: float) -> Loss:
    def value(w, x, y):
        return _lsq_value(w, x, y) + 0.5 * lam * jnp.dot(w, w)

    def grad(w, x, y):
        return _lsq_grad(w, x, y) + lam * w

    return Loss("ridge_least_squares", value, grad, lam=lam)


# --------------------------------------------------------------------------
# Logistic loss (App. E classification experiments): y in {-1, +1}
# --------------------------------------------------------------------------

def logistic() -> Loss:
    def value(w, x, y):
        return jnp.logaddexp(0.0, -y * jnp.dot(w, x))

    def grad(w, x, y):
        s = jax.nn.sigmoid(-y * jnp.dot(w, x))
        return -s * y * x

    return Loss("logistic", value, grad, lam=0.0)


def logistic_ridge(lam: float) -> Loss:
    base = logistic()

    def value(w, x, y):
        return base.value_fn(w, x, y) + 0.5 * lam * jnp.dot(w, w)

    def grad(w, x, y):
        return base.grad_fn(w, x, y) + lam * w

    return Loss("logistic_ridge", value, grad, lam=lam)


# --------------------------------------------------------------------------
# Batched closed forms for least squares (used by exact prox + tests)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def lsq_batch_value(w, X, y):
    r = X @ w - y
    return 0.5 * jnp.mean(r * r)


@partial(jax.jit, static_argnames=())
def lsq_batch_grad(w, X, y):
    n = X.shape[0]
    return X.T @ (X @ w - y) / n


def loss_constants(X, y=None, radius: float = None, lam: float = 0.0):
    """Empirical (L, beta) for least squares on a reference sample.

    beta = max_i ||x_i||^2 (per-example smoothness),
    L    = max_i ||x_i|| * (radius * ||x_i|| + |y_i|)  (Lipschitz over the ball
           of radius `radius`); the paper assumes L, beta = O(1).
    """
    norms = jnp.linalg.norm(X, axis=1)
    beta = jnp.max(norms**2) + lam
    if radius is None:
        radius = 1.0
    if y is None:
        y = jnp.zeros(X.shape[0])
    L = jnp.max(norms * (radius * norms + jnp.abs(y))) + lam * radius
    return float(L), float(beta)
