"""Resource accounting: communication, memory, computation.

The paper measures (Table 1):
  - communication: number of vectors averaged-and-redistributed per machine
  - memory: number of vectors stored per machine (samples count as vectors)
  - computation: vector operations per machine

Algorithms in repro.core thread a `Ledger` through their loops; benchmarks
compare the measured numbers against `theory.table1_resources`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Ledger:
    comm_rounds: int = 0          # averaging/broadcast rounds
    comm_vectors: int = 0         # vectors communicated per machine
    vector_ops: int = 0           # per-machine vector operations
    peak_memory_vectors: int = 0  # max vectors simultaneously held per machine

    def communicate(self, vectors: int = 1, rounds: int = 1):
        self.comm_rounds += rounds
        self.comm_vectors += vectors

    def compute(self, ops: int):
        self.vector_ops += ops

    def hold(self, vectors: int):
        self.peak_memory_vectors = max(self.peak_memory_vectors, vectors)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __add__(self, other: "Ledger") -> "Ledger":
        return Ledger(
            comm_rounds=self.comm_rounds + other.comm_rounds,
            comm_vectors=self.comm_vectors + other.comm_vectors,
            vector_ops=self.vector_ops + other.vector_ops,
            peak_memory_vectors=max(self.peak_memory_vectors,
                                    other.peak_memory_vectors),
        )
