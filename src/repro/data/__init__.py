from repro.data.synthetic import LeastSquaresStream, TokenStream  # noqa: F401
