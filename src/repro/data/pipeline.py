"""Host-side data pipeline: sharded, deterministic, prefetching.

The paper's streaming model means the pipeline is stateless given
(seed, step): every machine/process draws its own shard of the global
minibatch by folding (step, shard_index) into the key — restarts and
elastic re-sharding need no pipeline state (DESIGN.md §6).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp


class ShardedBatcher:
    """Deterministic per-step global batches, optionally restricted to this
    process's shard (for multi-host data loading)."""

    def __init__(self, sample_fn: Callable, global_batch: int,
                 n_shards: int = 1, shard_index: int = 0, seed: int = 0):
        assert global_batch % n_shards == 0
        self.sample_fn = sample_fn          # (key, n) -> pytree of arrays
        self.global_batch = global_batch
        self.n_shards = n_shards
        self.shard_index = shard_index
        self.seed = seed

    def batch_at(self, step: int):
        """The shard of the global batch for `step` (pure function)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            self.shard_index)
        return self.sample_fn(key, self.global_batch // self.n_shards)

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Overlaps host batch construction with device compute (depth-bounded
    background thread)."""

    def __init__(self, iterator, depth: int = 2):
        self._it = iter(iterator)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(_SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is _SENTINEL:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class _Sentinel:
    pass


_SENTINEL = _Sentinel()
