"""Seeded synthetic data streams.

The paper's setting is *stochastic* optimization: examples arrive from an
unknown distribution D one at a time ("a button generating examples"). We model
this with stateless seeded generators so that (a) any machine can draw its own
minibatch independently, (b) restarts regenerate identical streams, and (c) no
dataset ever needs to be stored (the paper's memory model).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LeastSquaresStream:
    """y = x . w_star + noise, x ~ N(0, Sigma) with decaying spectrum.

    Conditioning is controlled by `decay`: eigenvalues lam_j ~ j^{-decay}.
    Feature norm is scaled so beta = max ||x||^2 = O(1).
    """

    dim: int
    noise: float = 0.1
    decay: float = 0.5
    seed: int = 0

    def _spectrum(self):
        j = np.arange(1, self.dim + 1, dtype=np.float64)
        lam = j ** (-self.decay)
        lam = lam / lam.sum() * self.dim  # trace = d
        return jnp.asarray(np.sqrt(lam), dtype=jnp.float32)

    def w_star(self):
        key = jax.random.PRNGKey(self.seed)
        w = jax.random.normal(key, (self.dim,))
        return w / jnp.linalg.norm(w)

    def sample(self, key, n: int):
        """Draw n fresh examples. Returns X: (n, d), y: (n,)."""
        kx, kn = jax.random.split(key)
        scale = self._spectrum()
        X = jax.random.normal(kx, (n, self.dim)) * scale / jnp.sqrt(self.dim)
        y = X @ self.w_star() + self.noise * jax.random.normal(kn, (n,))
        return X, y

    def sample_distributed(self, key, m: int, b: int):
        """Each of m machines draws b examples: X (m, b, d), y (m, b)."""
        X, y = self.sample(key, m * b)
        return X.reshape(m, b, self.dim), y.reshape(m, b)

    def population_objective(self, w, n_eval: int = 65536, seed: int = 10**6):
        """Monte-Carlo estimate of phi(w) on a fresh evaluation sample."""
        X, y = self.sample(jax.random.PRNGKey(seed), n_eval)
        r = X @ w - y
        return 0.5 * jnp.mean(r * r)

    def population_suboptimality(self, w, n_eval: int = 65536):
        """phi(w) - phi(w_star_emp) with a shared eval set (variance-reduced)."""
        X, y = self.sample(jax.random.PRNGKey(10**6), n_eval)
        # Population optimum of the noisy model is w_star itself.
        r = X @ w - y
        r_star = X @ self.w_star() - y
        return 0.5 * jnp.mean(r * r) - 0.5 * jnp.mean(r_star * r_star)


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Deterministic synthetic token stream for LM training/serving tests.

    Produces (tokens, targets) with a learnable structure: targets are a fixed
    permutation-shift of tokens so tiny models can overfit it, which the smoke
    and integration tests use to check that training reduces loss.
    """

    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, key, batch_size: int):
        toks = jax.random.randint(
            key, (batch_size, self.seq_len + 1), 0, self.vocab_size
        )
        # next-token structure: x_{t+1} = (x_t * 31 + 7) % V on half of positions
        det = (toks[:, :-1] * 31 + 7) % self.vocab_size
        mix = jax.random.bernoulli(jax.random.fold_in(key, 1),
                                   0.5, det.shape)
        inputs = toks[:, :-1]
        targets = jnp.where(mix, det, toks[:, 1:])
        return inputs, targets
