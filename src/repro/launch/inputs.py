"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

`input_specs(cfg, shape, mesh)` returns the argument pytree for the step
function of that shape kind, with NamedShardings attached — the dry-run
lowers against these directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import data_axes, dp_axes_for
from repro.models import lm

N_MICRO = 8  # microbatches per held minibatch (train shapes)


def _sds(shape, dtype, mesh, spec):
    spec = shd.sanitize_spec(spec, shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def train_batch_struct(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B, S = shape.global_batch, shape.seq_len
    # dp_only archs shard the batch over the whole mesh: one big held
    # minibatch (n_micro=1), multiple inner passes (paper: any b works)
    n_micro = 1 if cfg.parallelism == "dp_only" else min(N_MICRO, B)
    Bm = B // n_micro
    dp = dp_axes_for(cfg, mesh, batch=Bm)
    mspec = P(None, dp)
    batch = {}
    if cfg.frontend == "vision":
        s_text = S - cfg.vision_tokens
        batch["tokens"] = _sds((n_micro, Bm, s_text), jnp.int32, mesh, mspec)
        batch["targets"] = _sds((n_micro, Bm, s_text), jnp.int32, mesh, mspec)
        batch["vision_emb"] = _sds(
            (n_micro, Bm, cfg.vision_tokens, cfg.vision_dim),
            jnp.bfloat16, mesh, mspec)
    elif cfg.frontend == "audio":
        batch["tokens"] = _sds((n_micro, Bm, S, cfg.n_codebooks), jnp.int32,
                               mesh, mspec)
        batch["targets"] = _sds((n_micro, Bm, S, cfg.n_codebooks), jnp.int32,
                                mesh, mspec)
    else:
        batch["tokens"] = _sds((n_micro, Bm, S), jnp.int32, mesh, mspec)
        batch["targets"] = _sds((n_micro, Bm, S), jnp.int32, mesh, mspec)
    return batch


def prefill_batch_struct(cfg: ModelConfig, shape: ShapeConfig, mesh):
    dp = dp_axes_for(cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    mspec = P(dp)
    batch = {}
    if cfg.frontend == "vision":
        s_text = S - cfg.vision_tokens
        batch["tokens"] = _sds((B, s_text), jnp.int32, mesh, mspec)
        batch["vision_emb"] = _sds((B, cfg.vision_tokens, cfg.vision_dim),
                                   jnp.bfloat16, mesh, mspec)
    elif cfg.frontend == "audio":
        batch["tokens"] = _sds((B, S, cfg.n_codebooks), jnp.int32, mesh,
                               mspec)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, mspec)
    return batch


def decode_inputs_struct(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(state, tokens, pos) structs for decode_step."""
    dp = dp_axes_for(cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    state_shapes = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, B, max_len=S))
    specs = shd.decode_state_specs(state_shapes, cfg, dp)
    state = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), state_shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if cfg.frontend == "audio":
        tokens = _sds((B, cfg.n_codebooks), jnp.int32, mesh, P(dp))
    else:
        tokens = _sds((B,), jnp.int32, mesh, P(dp))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return state, tokens, pos


def params_struct(cfg: ModelConfig, mesh):
    """Sharded ShapeDtypeStructs for the param pytree (no allocation)."""
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(shapes, cfg)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)), specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Dispatch on shape kind; returns the step-function argument structs."""
    if shape.kind == "train":
        return train_batch_struct(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_batch_struct(cfg, shape, mesh)
    if shape.kind == "decode":
        return decode_inputs_struct(cfg, shape, mesh)
    raise ValueError(shape.kind)
