"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def generate(params, cfg, prompts, gen_len: int, *, temperature: float = 0.0,
             seed: int = 0):
    """prompts: (B, P) int32 (or (B, P, n_cb) audio). Greedy/temperature
    decode with a KV cache primed token-by-token from the prompt."""
    B = prompts.shape[0]
    P = prompts.shape[1]
    max_len = P + gen_len + 1
    state = lm.init_decode_state(cfg, B, max_len=max_len)
    step = jax.jit(lambda s, t, p: lm.decode_step(params, cfg, s, t, p))

    # prime the cache on the prompt
    logits = None
    for pos in range(P):
        logits, state = step(state, prompts[:, pos], jnp.int32(pos))

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    key = jax.random.PRNGKey(seed)
    for i in range(gen_len):
        out.append(tok)
        logits, state = step(state, tok, jnp.int32(P + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, -1)
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.frontend == "audio":
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len,
                                      cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
    with jax.set_mesh(mesh):
        t0 = time.time()
        tokens = generate(params, cfg, prompts, args.gen,
                          temperature=args.temperature)
        dt = time.time() - t0
    n_tok = tokens.shape[0] * tokens.shape[1]
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batched)")
    print(tokens[0][:16])


if __name__ == "__main__":
    main()
