"""Serving CLI: continuous-batching engine (default) or the legacy
fixed-batch path, with an open-loop synthetic traffic generator and
throughput/latency telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 32 --slots 8 --prompt-len 24 64 --max-new 8 32 --rate 50

    # shared-prefix traffic (system prompt + per-request suffix) with
    # prefix caching and explicit prefill length buckets:
    PYTHONPATH=src python -m repro.launch.serve --workload shared-prefix \
        --prefix-len 48 --prefix-cache --prefill-buckets 16 32 64

    # n-gram speculative decoding (greedy lanes stay bit-identical to
    # generate()) on a repetitive-text workload:
    PYTHONPATH=src python -m repro.launch.serve --workload repetitive \
        --speculate 4 --draft ngram --max-new 16 32

    # per-request sampling (position-keyed: batch-composition
    # independent) with nucleus/top-k warping and a stop sequence —
    # composes with speculation (distribution-preserving accept/reject):
    PYTHONPATH=src python -m repro.launch.serve --temperature 0.8 \
        --top-k 50 --top-p 0.95 --stop 7 11 --speculate 4

    # multi-replica cluster: a router fronting N full engine stacks
    # (per-replica device pools + prefix caches) with least-loaded or
    # prefix-affinity placement; outputs are bit-identical to a
    # single-replica run (batch-composition independence, one level up):
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 \
        --router prefix --workload multi-tenant --tenants 4

    # elastic autoscaling on bursty traffic: start one replica, scale
    # out (jit-warm standby stacks) under sustained queue pressure and
    # drain back when the burst passes; outputs stay bit-identical to
    # a fixed-size run. Mix priority classes to exercise preemption:
    PYTHONPATH=src python -m repro.launch.serve --workload bursty \
        --autoscale --min-replicas 1 --max-replicas 3 --priorities 0 1

    # observability: export a Perfetto-loadable trace (request lifecycle
    # spans per slot + the dispatch timeline) and a metrics dump
    # (counters/gauges/histograms + occupancy time series); outputs stay
    # bit-identical with the recorder on:
    PYTHONPATH=src python -m repro.launch.serve --requests 8 \
        --trace-out trace.json --metrics-out metrics.json

    # SLO layer: declare a TTFT objective (streaming latency sketches +
    # burn-rate windows), shed hopeless requests against a per-request
    # deadline, scale on burn rate instead of queue depth, and arm the
    # anomaly flight recorder on diurnal (sinusoidal-rate) traffic:
    PYTHONPATH=src python -m repro.launch.serve --workload diurnal \
        --slo-ttft-ms 100 --slo-shed --deadline-ms 500 \
        --autoscale --slo-autoscale --flight-recorder flight.json

    # legacy single-batch path (token-by-token cache priming; kept as the
    # benchmark baseline and for the audio/vision frontends):
    PYTHONPATH=src python -m repro.launch.serve --mode naive --batch 4

`generate()` below is the seed serving path, unchanged: it primes the KV
cache one token at a time and decodes a fixed batch in lockstep. The
engine replaces it for sustained traffic — see repro.serving.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.serving.autoscaler import Autoscaler, AutoscalePolicy
from repro.serving.engine import (Request, ServingEngine, bursty_requests,
                                  diurnal_requests,
                                  long_document_requests,
                                  multi_tenant_requests,
                                  repetitive_requests,
                                  shared_prefix_requests, summarize,
                                  synthetic_requests)
from repro.serving.observability import (NULL_OBS, FlightRecorder,
                                         Observability, export_metrics,
                                         export_trace,
                                         validate_metrics_dump,
                                         validate_trace_events)
from repro.serving.replica import Replica
from repro.serving.router import Router, summarize_cluster
from repro.serving.sampling import SamplingParams
from repro.serving.slo import SLOPolicy, SLOSignal, SLOTracker


# module-level so repeated generate() calls with the same shapes reuse the
# compiled step (cfg is a frozen dataclass => a valid static argument)
_decode_step_jit = jax.jit(lm.decode_step, static_argnums=(1,))


def generate(params, cfg, prompts, gen_len: int, *, temperature: float = 0.0,
             seed: int = 0):
    """prompts: (B, P) int32 (or (B, P, n_cb) audio). Greedy/temperature
    decode with a KV cache primed token-by-token from the prompt."""
    B = prompts.shape[0]
    P = prompts.shape[1]
    max_len = P + gen_len + 1
    state = lm.init_decode_state(cfg, B, max_len=max_len)

    def step(s, t, p):
        return _decode_step_jit(params, cfg, s, t, p)

    # prime the cache on the prompt
    logits = None
    for pos in range(P):
        logits, state = step(state, prompts[:, pos], jnp.int32(pos))

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    key = jax.random.PRNGKey(seed)
    for i in range(gen_len):
        out.append(tok)
        logits, state = step(state, tok, jnp.int32(P + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, -1)
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def _prompt_len_spec(values):
    """One int = fixed length; two ints = uniform (lo, hi) mixed."""
    if len(values) == 1:
        return values[0]
    if len(values) == 2:
        return (values[0], values[1])
    raise SystemExit("--prompt-len takes one or two ints")


def _sampling_from_args(args):
    """Per-workload SamplingParams from the CLI flags; None (greedy,
    no stops, no logprobs) when every flag sits at its default."""
    stop = (tuple(args.stop),) if args.stop else ()
    if (args.temperature <= 0 and args.top_k == 0 and args.top_p >= 1.0
            and not stop and args.logprobs == 0):
        return None
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.seed, stop=stop,
                          logprobs=args.logprobs)


def _make_workload(args, cfg):
    rate = float("inf") if args.rate <= 0 else args.rate
    plen = _prompt_len_spec(args.prompt_len)
    sampling = _sampling_from_args(args)
    if args.deadline_ms is not None:
        # stamp a per-request soft TTFT deadline: decoding is unchanged;
        # under --slo-shed the scheduler sheds requests that cannot make
        # it and admits tighter deadlines first within a priority class
        base = sampling if sampling is not None else SamplingParams()
        sampling = dataclasses.replace(base, deadline_ms=args.deadline_ms)
    if args.workload == "shared-prefix":
        return shared_prefix_requests(
            args.requests, vocab_size=cfg.vocab_size,
            prefix_len=args.prefix_len, suffix_len=plen,
            max_new=tuple(args.max_new), n_prefixes=args.n_prefixes,
            rate=rate, sampling=sampling, seed=args.seed)
    if args.workload == "multi-tenant":
        return multi_tenant_requests(
            args.requests, vocab_size=cfg.vocab_size,
            n_tenants=args.tenants, prefix_len=args.prefix_len,
            suffix_len=plen, max_new=tuple(args.max_new), rate=rate,
            tenant_priorities=args.tenant_priorities,
            sampling=sampling, seed=args.seed)
    if args.workload == "bursty":
        return bursty_requests(
            args.requests, vocab_size=cfg.vocab_size,
            base_rate=args.base_rate, burst_rate=args.burst_rate,
            burst_every=args.burst_every, burst_len=args.burst_len,
            prompt_len=plen, max_new=tuple(args.max_new),
            priorities=tuple(args.priorities), sampling=sampling,
            seed=args.seed)
    if args.workload == "diurnal":
        return diurnal_requests(
            args.requests, vocab_size=cfg.vocab_size,
            rate_min=args.rate_min, rate_max=args.rate_max,
            period=args.diurnal_period, prompt_len=plen,
            max_new=tuple(args.max_new),
            priorities=tuple(args.priorities), sampling=sampling,
            seed=args.seed)
    if args.workload == "repetitive":
        return repetitive_requests(
            args.requests, vocab_size=cfg.vocab_size, period=args.period,
            prompt_len=plen, max_new=tuple(args.max_new), rate=rate,
            sampling=sampling, seed=args.seed)
    if args.workload == "long-document":
        return long_document_requests(
            args.requests, vocab_size=cfg.vocab_size, prompt_len=plen,
            max_new=tuple(args.max_new), rate=rate, sampling=sampling,
            seed=args.seed)
    return synthetic_requests(
        args.requests, vocab_size=cfg.vocab_size, prompt_len=plen,
        max_new=tuple(args.max_new), rate=rate, sampling=sampling,
        seed=args.seed)


def _engine_kwargs(args, max_seq_len):
    return dict(num_slots=args.slots, block_size=args.block_size,
                max_seq_len=max_seq_len, prefix_cache=args.prefix_cache,
                prefill_buckets=args.prefill_buckets,
                prefill_max_batch=args.prefill_batch,
                prefill_chunk=args.prefill_chunk,
                speculate=args.speculate, draft=args.draft,
                ngram=args.ngram, kv_dtype=args.kv_dtype,
                host_cache_blocks=args.host_cache_blocks,
                priority_aging=args.priority_aging,
                # widen the compiled top-k side output when the CLI asks
                # for more alternatives than the engine default carries
                max_logprobs=max(args.logprobs, 8))


def _slo_from_args(args):
    """(SLOPolicy, SLOTracker) when any SLO flag asks for the layer,
    else (None, None) — the default path carries zero SLO state."""
    slo_on = (args.slo_ttft_ms is not None
              or args.slo_latency_ms is not None
              or args.slo_shed or args.slo_autoscale)
    if not slo_on:
        return None, None
    policy = SLOPolicy(
        ttft_objective_ms=(args.slo_ttft_ms if args.slo_ttft_ms is not None
                           else 200.0),
        latency_objective_ms=args.slo_latency_ms,
        error_budget=args.slo_budget)
    return policy, SLOTracker(policy)


def _run_engine(args, cfg, params):
    reqs = _make_workload(args, cfg)
    max_prompt = max(len(r.prompt) for r in reqs)
    kwargs = _engine_kwargs(args, max_prompt + max(args.max_new) + 1)
    slo_policy, slo_tracker = _slo_from_args(args)
    # the recorder is on only when an export was asked for — the default
    # NULL_OBS path records nothing and adds no work (and outputs are
    # bit-identical either way). --flight-recorder implies the recorder:
    # the ring is fed by the same instruments.
    recorder = (FlightRecorder(dump_path=args.flight_recorder)
                if args.flight_recorder else None)
    tracing = bool(args.trace_out or args.metrics_out
                   or args.flight_recorder)
    obs = Observability(recorder=recorder) if tracing else NULL_OBS
    if slo_tracker is not None:
        kwargs["slo_tracker"] = slo_tracker
        kwargs["slo_shed"] = args.slo_shed
        if obs.enabled:
            obs.slo = slo_tracker    # root view: metrics_dump sketches
    if args.autoscale:
        # elastic cluster: the router starts with min_replicas enabled
        # stacks; the rest are built up front and parked in the
        # autoscaler's standby pool, to be activated (jit-warm) when
        # sustained queue pressure demands it and drained back when the
        # burst passes. Outputs stay bit-identical to any fixed size.
        n_max = max(args.max_replicas, args.min_replicas)
        replicas = [Replica(params, cfg, replica_id=i, obs=obs, **kwargs)
                    for i in range(n_max)]
        router = Router(replicas[:args.min_replicas], policy=args.router,
                        obs=obs)
        policy = AutoscalePolicy(
            min_replicas=args.min_replicas, max_replicas=n_max,
            queue_high=args.queue_high, queue_low=args.queue_low,
            cooldown_s=args.scale_cooldown)
        controller = (SLOSignal(slo_tracker, policy, obs=obs)
                      if args.slo_autoscale else None)
        Autoscaler(router, policy=policy,
                   standby=replicas[args.min_replicas:], obs=obs,
                   controller=controller)
        done = router.run(reqs)
        stats = summarize_cluster(done, router.wall_time, router)
    elif args.replicas > 1:
        replicas = [Replica(params, cfg, replica_id=i, obs=obs, **kwargs)
                    for i in range(args.replicas)]
        router = Router(replicas, policy=args.router, obs=obs)
        done = router.run(reqs)
        stats = summarize_cluster(done, router.wall_time, router)
    else:
        engine = ServingEngine(params, cfg, obs=obs, **kwargs)
        done = engine.run(reqs)
        stats = summarize(done, engine.wall_time, engine)
    if slo_tracker is not None and "slo" not in stats:
        # cluster paths: summarize_cluster has no engine handle, so the
        # shared tracker's snapshot is attached here
        stats["slo"] = slo_tracker.snapshot()
    if args.flight_recorder:
        doc = recorder.dump()
        errs = validate_trace_events(doc)
        if errs:
            raise SystemExit(f"invalid flight-recorder dump: {errs[:3]}")
        fr = doc["otherData"]["flight_recorder"]
        print(f"flight recorder: {fr['events']} events "
              f"({fr['dropped']} dropped, {len(fr['anomalies'])} "
              f"anomalies) to {args.flight_recorder}")
    if args.trace_out:
        doc = export_trace(obs, args.trace_out)
        errs = validate_trace_events(doc)
        if errs:
            raise SystemExit(f"invalid trace_event export: {errs[:3]}")
        print(f"wrote {len(doc['traceEvents'])} trace events "
              f"to {args.trace_out} (open in https://ui.perfetto.dev)")
    if args.metrics_out:
        doc = export_metrics(obs, args.metrics_out)
        errs = validate_metrics_dump(doc)
        if errs:
            raise SystemExit(f"invalid metrics dump: {errs[:3]}")
        print(f"wrote metrics ({len(doc['counters'])} counters, "
              f"{len(doc['series'])} series samples) to {args.metrics_out}")
    print(json.dumps(stats, indent=1))
    if done:
        sample = min(done, key=lambda c: c.rid)
        print(f"sample (req {sample.rid}): {sample.tokens[:16]}")


def _run_naive(args, cfg, params):
    plen = args.prompt_len[0]     # naive path is fixed-shape by design
    if cfg.frontend == "audio":
        prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                     (args.batch, plen,
                                      cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                     (args.batch, plen), 0,
                                     cfg.vocab_size)
    t0 = time.time()
    tokens = generate(params, cfg, prompts, max(args.max_new),
                      temperature=args.temperature)
    dt = time.time() - t0
    n_tok = tokens.shape[0] * tokens.shape[1]
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batched)")
    print(np.asarray(tokens[0][:16]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--mode", default="engine", choices=["engine", "naive"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4,
                    help="fixed batch for --mode naive")
    ap.add_argument("--prompt-len", type=int, nargs="+", default=[64],
                    help="fixed length, or LO HI for mixed-length traffic "
                         "(suffix length under --workload shared-prefix)")
    ap.add_argument("--max-new", type=int, nargs=2, default=(8, 32),
                    metavar=("LO", "HI"))
    ap.add_argument("--workload", default="synthetic",
                    choices=["synthetic", "shared-prefix", "multi-tenant",
                             "repetitive", "long-document", "bursty",
                             "diurnal"])
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="shared system-prompt length (shared-prefix / "
                         "multi-tenant)")
    ap.add_argument("--n-prefixes", type=int, default=1,
                    help="distinct system prompts (shared-prefix)")
    ap.add_argument("--tenants", type=int, default=4,
                    help="distinct tenants, each with its own shared "
                         "prefix, interleaved arrivals (multi-tenant)")
    ap.add_argument("--tenant-priorities", type=int, nargs="+", default=None,
                    help="per-tenant scheduler priority classes (one int "
                         "per tenant; higher preempts lower — an SLO mix "
                         "for --workload multi-tenant)")
    ap.add_argument("--base-rate", type=float, default=4.0,
                    help="off-burst arrival rate req/s (bursty)")
    ap.add_argument("--burst-rate", type=float, default=64.0,
                    help="in-burst arrival rate req/s (bursty)")
    ap.add_argument("--burst-every", type=float, default=2.0,
                    help="burst cycle period in seconds (bursty)")
    ap.add_argument("--burst-len", type=float, default=0.25,
                    help="burst duration per cycle in seconds (bursty)")
    ap.add_argument("--priorities", type=int, nargs="+", default=[0],
                    help="priority classes drawn uniformly per request "
                         "(bursty / diurnal)")
    ap.add_argument("--rate-min", type=float, default=1.0,
                    help="trough arrival rate req/s (diurnal)")
    ap.add_argument("--rate-max", type=float, default=32.0,
                    help="peak arrival rate req/s (diurnal)")
    ap.add_argument("--diurnal-period", type=float, default=8.0,
                    help="seconds per sinusoidal rate cycle (diurnal)")
    ap.add_argument("--priority-aging", type=float, default=2.0,
                    help="seconds of queue wait worth one priority class "
                         "at admission (starvation bound; <=0 disables)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas behind the cluster router "
                         "(each a full engine stack; 1 = no router)")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic cluster: start --min-replicas, scale "
                         "out to --max-replicas under sustained queue "
                         "pressure and drain back when idle (overrides "
                         "--replicas)")
    ap.add_argument("--min-replicas", type=int, default=1,
                    help="enabled replicas at run start (--autoscale)")
    ap.add_argument("--max-replicas", type=int, default=3,
                    help="replica ceiling; the surplus stacks are built "
                         "up front as the jit-warm standby pool "
                         "(--autoscale)")
    ap.add_argument("--queue-high", type=float, default=2.0,
                    help="per-replica queue depth that accumulates "
                         "toward a scale-out (--autoscale)")
    ap.add_argument("--queue-low", type=float, default=1.0,
                    help="per-replica load at or below which idleness "
                         "accumulates toward a scale-in (--autoscale)")
    ap.add_argument("--scale-cooldown", type=float, default=0.25,
                    help="minimum seconds between scaling decisions "
                         "(--autoscale)")
    ap.add_argument("--router", default="least-loaded",
                    choices=["rr", "least-loaded", "prefix"],
                    help="replica placement policy: round-robin, "
                         "least-loaded (slot+queue occupancy), or "
                         "prefix-affinity (BlockAllocator match_prefix "
                         "probe)")
    ap.add_argument("--period", type=int, default=6,
                    help="repeated-pattern length (repetitive)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="max draft tokens per verify dispatch "
                         "(speculative decoding; 0 = off, greedy-only)")
    ap.add_argument("--draft", default="ngram", choices=["ngram"],
                    help="draft proposer (ngram = prompt lookup)")
    ap.add_argument("--ngram", type=int, default=3,
                    help="longest n-gram the proposer matches")
    ap.add_argument("--kv-dtype", default="fp16",
                    choices=["fp16", "int8", "fp8"],
                    help="paged KV pool storage dtype: fp16 keeps the "
                         "model activation dtype (bit-identical), "
                         "int8/fp8 quantize blocks on landing with "
                         "per-slot-per-head scale tables")
    ap.add_argument("--host-cache-blocks", type=int, default=0,
                    help="host-RAM spill tier capacity in KV blocks: "
                         "evicted cached prefix blocks demote to pinned "
                         "host memory and revive on a later prefix hit "
                         "(0 = off)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="share cached prompt-prefix blocks (default: auto "
                         "— on for pure-attention archs)")
    ap.add_argument("--prefill-buckets", type=int, nargs="+", default=None,
                    help="suffix-length buckets for batched prefill "
                         "(default: powers of two up to max_seq_len)")
    ap.add_argument("--prefill-batch", type=int, default=4,
                    help="max prompts admitted per prefill dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-admission budget: prompts longer than "
                         "the largest prefill bucket are admitted in "
                         "chunks of this many tokens, one per engine "
                         "step (default 2048; 0 disables — oversized "
                         "prompts are then rejected at submit)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate req/s (<=0: all at t=0)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy; "
                         "each request gets its own PRNG stream)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--stop", type=int, nargs="+", default=None,
                    help="stop token sequence: generation ends when the "
                         "output ends with these ids")
    ap.add_argument("--logprobs", type=int, default=0,
                    help="record the chosen token's logprob plus the "
                         "top-k alternatives per position (0 = off)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT objective in ms: turns on the SLO layer "
                         "(streaming latency sketches + burn-rate "
                         "windows; see repro.serving.slo)")
    ap.add_argument("--slo-latency-ms", type=float, default=None,
                    help="end-to-end latency objective in ms (optional "
                         "second SLO besides TTFT)")
    ap.add_argument("--slo-budget", type=float, default=0.1,
                    help="error budget: tolerated fraction of requests "
                         "over objective (burn rate 1.0 = spending "
                         "exactly this budget)")
    ap.add_argument("--slo-shed", action="store_true",
                    help="SLO-aware admission: order by deadline slack "
                         "within a priority class and shed requests "
                         "whose --deadline-ms cannot be met (OFF by "
                         "default — without it admission order and "
                         "outputs are untouched)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request soft TTFT deadline in ms after "
                         "arrival (stamped on every request; acted on "
                         "only under --slo-shed)")
    ap.add_argument("--slo-autoscale", action="store_true",
                    help="drive --autoscale decisions from the TTFT "
                         "burn rate (SLOSignal) instead of queue depth")
    ap.add_argument("--flight-recorder", default=None, metavar="PATH",
                    help="always-on bounded ring of recent trace events; "
                         "dumps a Perfetto trace to PATH on anomalies "
                         "(TTFT breach, preemption storm, eviction "
                         "thrash) and at end of run. Enables the "
                         "observability recorder.")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON of "
                         "the run (request lifecycle spans per slot, "
                         "dispatch timeline; open in ui.perfetto.dev). "
                         "Enables the observability recorder.")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry dump JSON (counters/"
                         "gauges/histograms + SchedulerStats time series). "
                         "Enables the observability recorder.")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.slo_autoscale and not args.autoscale:
        raise SystemExit("--slo-autoscale requires --autoscale")

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    with compat.set_mesh(mesh):
        if args.mode == "engine":
            _run_engine(args, cfg, params)
        else:
            _run_naive(args, cfg, params)


if __name__ == "__main__":
    main()
