"""Step-function builders: train (MBProx paper-faithful / baseline AdamW),
prefill, decode — shared by the dry-run, the training driver and benchmarks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import data_axes, dp_axes_for
from repro.models import lm
from repro.optim import mbprox as mbprox_lib
from repro.optim.optimizers import adamw, clip_by_global_norm, sgd


def make_loss_fn(cfg: ModelConfig, remat: bool = True):
    def loss_fn(params, micro):
        return lm.train_loss(params, cfg, micro, remat=remat)
    return loss_fn


# ----------------------------------------------------------------------------
# Baseline: data-parallel AdamW, gradient accumulated over microbatches.
# Collective profile: one grad all-reduce over data(+pod) per microbatch —
# the "minibatch SGD" communication model of the paper's Table 1.
# ----------------------------------------------------------------------------

def make_baseline_train_step(cfg: ModelConfig, mesh):
    loss_fn = make_loss_fn(cfg)
    opt = adamw(state_dtype=jnp.bfloat16
                if shd.needs_fsdp(cfg) else None)

    def train_step(params, opt_state, batch, lr):
        def micro_grad(carry, micro):
            acc = carry
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                  micro)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(a.dtype), acc, g)
            return acc, l

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16
                                if shd.needs_fsdp(cfg) else jnp.float32),
            params)
        n_micro = jax.tree.leaves(batch)[0].shape[0]
        grads, losses = lax.scan(micro_grad, zeros, batch)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": losses.mean(), "gnorm": gnorm}

    return train_step, opt


# ----------------------------------------------------------------------------
# Paper technique: MBProx train step (local MP-DANE form or sync inexact form)
# ----------------------------------------------------------------------------

def default_mbprox_config(cfg: ModelConfig,
                          **overrides) -> mbprox_lib.MBProxConfig:
    variant = "sync" if shd.needs_fsdp(cfg) else "local"
    base = dict(gamma=0.1, inner_lr=0.02, inner_momentum=0.9,
                inner_passes=1, dane_correction=True, variant=variant)
    base.update(overrides)
    return mbprox_lib.MBProxConfig(**base)


def make_mbprox_train_step(cfg: ModelConfig, mesh,
                           mp_cfg: Optional[mbprox_lib.MBProxConfig] = None,
                           micro_batch: Optional[int] = None):
    mp_cfg = mp_cfg or default_mbprox_config(cfg)
    loss_fn = make_loss_fn(cfg)
    dp = dp_axes_for(cfg, mesh, batch=micro_batch)
    step = mbprox_lib.make_mbprox_step(loss_fn, mp_cfg, mesh, dp)
    inner_opt = sgd(momentum=mp_cfg.inner_momentum)

    def train_step(params, inner_state, batch, lr):
        return step(params, inner_state, batch, lr)

    return train_step, inner_opt, mp_cfg


# ----------------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, _ = lm.forward(params, cfg, batch, remat=False)
        return logits
    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, state, tokens, pos):
        return lm.decode_step(params, cfg, state, tokens, pos)
    return decode
