"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally, as a ('data','model') mesh — used by
    examples/tests so the same sharded code paths run on 1 CPU device."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axis names batch is sharded over (includes 'pod' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_axes_for(cfg, mesh, batch: int | None = None) -> tuple:
    """Data-parallel axes for an arch: 'dp_only' archs also fold the model
    axis into data parallelism (params replicated). When `batch` is given,
    the axis tuple is trimmed to the longest prefix that divides it (e.g.
    batch 256 on the 512-chip multi-pod mesh -> ('pod','data'))."""
    if getattr(cfg, "parallelism", "tp") == "dp_only":
        axes = tuple(mesh.axis_names)
    else:
        axes = data_axes(mesh)
    if batch is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        while axes:
            ways = 1
            for a in axes:
                ways *= sizes[a]
            if batch % ways == 0:
                break
            axes = axes[:-1]
    return axes
