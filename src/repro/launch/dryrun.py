import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (device count locks on
# first init). Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell:
  * build the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  * construct ShapeDtypeStruct inputs via launch.inputs.input_specs,
  * jit the step (MBProx train / baseline train / prefill / decode),
  * .lower().compile() — failures here are bugs in the sharding config,
  * record memory_analysis(), cost_analysis() and parsed collective stats
    into experiments/dryrun/<cell>.json (incremental; reruns skip done cells).

Usage:
  python -m repro.launch.dryrun                       # all cells, both meshes
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k \
        --mesh single --variant mbprox
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch import (cost_model, hlo_analysis, inputs as inputs_lib,
                          steps as steps_lib)
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 512k dense decode is out of "
                "design scope (DESIGN.md §4)")
    return None


def model_flops(cfg, shape, inner_passes: int = 1) -> float:
    """Useful FLOPs per step: 6*N_active*tokens (train), 2*N_active*tokens
    (inference); decode = one token per sequence."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * inner_passes
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one new token


def _mirror_state_struct(opt_state_shapes, params):
    """Optimizer-state leaves mirror the param leaf sharding 1:1 where the
    subtree structure matches params (m/v/momentum); scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def walk(node):
        treedef_p = jax.tree.structure(params)
        if jax.tree.structure(node) == treedef_p:
            return jax.tree.map(
                lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                  sharding=p.sharding),
                node, params)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        # scalar counters etc.
        mesh = jax.tree.leaves(params)[0].sharding.mesh
        return jax.ShapeDtypeStruct(node.shape, node.dtype,
                                    sharding=NamedSharding(mesh, P()))

    return walk(opt_state_shapes)


def _register_inloop_specs(cfg, mesh):
    """Compute sliced-layer specs (stacked axis stripped) and register them
    for in-loop pinning (distributed/context.py)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import context as dctx

    from repro.models import lm
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                   cfg))
    specs = shd.param_specs(shapes, cfg)
    sliced_specs = {}
    for key, sub in specs["blocks"].items():
        sub_shapes = shapes["blocks"][key]
        sliced = jax.tree.map(
            lambda sp, s: shd.sanitize_spec(P(*tuple(sp)[1:]), s.shape[1:],
                                            mesh),
            sub, sub_shapes, is_leaf=lambda x: isinstance(x, P))
        sliced_specs[key] = sliced
    dctx.set_inloop_specs(sliced_specs)


def build_cell(cfg, shape, mesh, variant: str):
    """Returns (fn, args) ready for jit(fn).lower(*args)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import context as dctx
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    mbprox_local = (shape.kind == "train" and variant != "baseline"
                    and not shd.needs_fsdp(cfg))
    if shape.kind == "train":
        # train: per-layer FSDP gathers are loop-index-dependent (scan
        # slices) so LICM cannot hoist them; pinning would instead force
        # per-einsum activation psums over 'data' (measured 8.8 TB/step on
        # grok — EXPERIMENTS.md §Perf iteration 2)
        dctx.set_inloop_specs(None)
    else:
        # serve: weights stay sharded in-loop (2D TP), avoiding hoisted
        # whole-stack gathers at decode
        _register_inloop_specs(cfg, mesh)
    if mbprox_local:
        # inside shard_map the data axis is manual — constraints may only
        # reference auto axes; batch is local by construction
        dctx.set_activation_spec(None)
    else:
        # pin batch-over-data on layer activations so FSDP feature
        # shardings cannot steal the data axis (§Perf iteration 3)
        dctx.set_activation_spec(P(dp, None, None))
    ep = cfg.n_experts and cfg.n_experts % 16 == 0
    if (variant == "opt" and ep and shd.needs_fsdp(cfg)
            and shape.kind == "train"):
        # weight-stationary expert parallelism: route tokens to the expert
        # shards (xe resharded E@model, D@data — MBs) instead of FSDP-
        # gathering expert weights (GBs per layer visit); §Perf it. 9
        dctx.set_moe_gather_specs(None)
        dctx.set_moe_xe_spec(P(None, "model", None, "data"))
    elif cfg.n_experts and shd.needs_fsdp(cfg) and shape.kind == "train":
        dctx.set_moe_xe_spec(None)
        dctx.set_moe_gather_specs({
            "w_gate": P("model", None, None) if ep else P(None, None,
                                                          "model"),
            "w_up": P("model", None, None) if ep else P(None, None,
                                                        "model"),
            "w_down": P("model", None, None) if ep else P(None, "model",
                                                          None),
        })
    else:
        dctx.set_moe_gather_specs(None)
        dctx.set_moe_xe_spec(None)
    params, _ = inputs_lib.params_struct(cfg, mesh)
    if shape.kind == "train":
        batch = inputs_lib.input_specs(cfg, shape, mesh)
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        if variant == "baseline":
            step, opt = steps_lib.make_baseline_train_step(cfg, mesh)
            opt_state = _mirror_state_struct(jax.eval_shape(opt.init, params),
                                             params)
            return step, (params, opt_state, batch, lr)
        n_micro = jax.tree.leaves(batch)[0].shape[0]
        micro_b = jax.tree.leaves(batch)[0].shape[1]
        step, inner_opt, mp_cfg = steps_lib.make_mbprox_train_step(
            cfg, mesh, micro_batch=micro_b)
        inner_state = _mirror_state_struct(
            jax.eval_shape(inner_opt.init, params), params)
        return step, (params, inner_state, batch, lr)
    if shape.kind == "prefill":
        batch = inputs_lib.input_specs(cfg, shape, mesh)
        step = steps_lib.make_prefill_step(cfg)
        return step, (params, batch)
    # decode
    state, tokens, pos = inputs_lib.input_specs(cfg, shape, mesh)
    step = steps_lib.make_decode_step(cfg)
    return step, (params, state, tokens, pos)


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
             out_dir: str, force: bool = False) -> dict:
    cell_id = f"{arch}__{shape_name}__{mesh_kind}__{variant}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    import dataclasses as _dc
    cfg = get_config(arch)
    if variant == "opt":
        # beyond-paper perf variant: bisection-causal attention (halves the
        # S^2 attention FLOPs), dots-saveable remat (no re-forward), flash
        # kernels assumed for the memory model (§Perf)
        cfg = _dc.replace(cfg, attn_impl="bisect", remat_policy="dots")
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "status": "unknown"}
    reason = _skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        _write(out_path, rec)
        return rec

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_chips = mesh.devices.size
        fn, args = build_cell(cfg, shape, mesh, variant)
        # donate mutable state (params/opt for train, KV cache for decode) —
        # production aliasing; otherwise memory doubles
        donate = {"train": (0, 1), "decode": (1,),
                  "prefill": ()}[shape.kind]
        with compat.set_mesh(mesh):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        analysis = hlo_analysis.analyze_hlo(hlo)
        coll = analysis["collectives"]
        flops_per_chip = analysis["dot_flops"]
        hbm = cost_model.hbm_bytes(cfg, shape, n_chips, variant=variant,
                                   flash=(variant == "opt"))
        mf = model_flops(cfg, shape)
        roof = hlo_analysis.roofline(flops_per_chip, hbm["total"], coll,
                                     n_chips, mf,
                                     ew_flops=analysis["elementwise_flops"])
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_chips=n_chips,
            memory=_mem_dict(mem, hlo),
            xla_cost={k: cost.get(k) for k in
                      ("flops", "bytes accessed", "optimal_seconds")
                      if k in cost},
            hbm_model=hbm,
            collectives=coll,
            elementwise_flops=analysis["elementwise_flops"],
            roofline=roof.as_dict(),
        )
        print(f"[ok] {cell_id}: compile={t_compile:.0f}s "
              f"argbytes/dev={rec['memory'].get('argument_size_gb', '?')}GB "
              f"bottleneck={roof.bottleneck} mfu_bound={roof.mfu_bound:.3f}",
              flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERR] {cell_id}: {type(e).__name__}: {e}", flush=True)
    _write(out_path, rec)
    return rec


_UPCAST_RE = None


def _cpu_upcast_bytes(hlo: str) -> int:
    """Bytes of large f32 tensors produced by bf16->f32 `convert` ops.

    The CPU backend upcasts bf16 dot operands to f32 (TPU computes bf16
    natively), and hoists loop-invariant converts of whole weight stacks /
    KV caches out of while loops — inflating measured temp. We report those
    separately so the fits-16GB verdict reflects the TPU target.
    """
    import re
    # Pairing heuristic: every large f32[dims] tensor whose bf16[dims] twin
    # also exists in the module is (with overwhelming likelihood for this
    # codebase — all activations/weights are declared bf16) a CPU-backend
    # upcast: hoisted weight converts, loop-carried remat stacks, KV-cache
    # copies. Each unique shape is counted once (the resident copy).
    f32_shapes, bf16_shapes = set(), set()
    for m in re.finditer(r"(f32|bf16)\[([\d,]+)\]", hlo):
        (f32_shapes if m.group(1) == "f32" else bf16_shapes).add(m.group(2))
    total = 0
    for dims in f32_shapes & bf16_shapes:
        n = 4
        for d in dims.split(","):
            n *= int(d)
        if n >= 2**27:  # only large (>=128MB) copies matter
            total += n
    return total


def _mem_dict(mem, hlo: str = "") -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    gb = 1024**3
    if "argument_size_in_bytes" in out:
        out["argument_size_gb"] = round(out["argument_size_in_bytes"] / gb, 2)
    if "temp_size_in_bytes" in out:
        out["temp_size_gb"] = round(out["temp_size_in_bytes"] / gb, 2)
    total = (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0))
    out["total_gb"] = round(total / gb, 2)
    upcast = _cpu_upcast_bytes(hlo) if hlo else 0
    out["cpu_upcast_artifact_gb"] = round(upcast / gb, 2)
    adj = total - upcast
    out["tpu_adjusted_total_gb"] = round(adj / gb, 2)
    out["fits_16gb"] = bool(adj <= 16 * 1024**3)
    return out


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--variant", default="mbprox",
                    choices=["mbprox", "baseline", "opt"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                if SHAPES[shape].kind == "train":
                    variant = args.variant
                else:
                    variant = "opt" if args.variant == "opt" else "serve"
                results.append(run_cell(arch, shape, mesh_kind, variant,
                                        args.out, force=args.force))
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {ok} ok, {sk} skipped, {err} errors "
          f"/ {len(results)} cells")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
