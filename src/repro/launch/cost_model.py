"""Analytic per-chip HBM traffic model for the roofline memory term.

The dry-run compiles on the CPU backend whose fusion decisions do not mirror
TPU, so HBM bytes cannot be read off the compiled module; instead we model
them from first principles (MaxText-style) and record the formulas here.
FLOPs and collective bytes COME FROM THE COMPILED HLO (hlo_analysis.py) —
only the HBM term is analytic. The roofline compute term charges the
HLO's dot FLOPs to the MXU and its elementwise FLOPs to the VPU (1/64 of
MXU peak — see hlo_analysis.VPU_FLOPS), so softmax/norm-heavy decode
steps are no longer bounded by their matmul time alone.

Traffic components per chip per step (bytes, bf16 activations):

  weights      train: 3 reads/step (fwd + bwd + gather-write for FSDP)
               x inner steps; + optimizer update (master/state r+w, fp32)
               serve: 1 read/step
  activations  train: per layer, C_act * tokens_loc * d_model * 2B
               (C_act=12: qkvo/mlp/norm in-out, x2 for backward, with remat
               recompute included); prefill: C_act=6 (no backward)
  attention    non-flash chunked path: scores+probs round trips
               3 * B_loc*H_loc*S*S*4B / (real flash kernel removes this)
  kv-cache     decode: full cache read per token + one slot write
  moe dispatch dispatch/combine tensors (+ all expert weights read — the
               static-capacity einsum touches every expert)
  logits       head output r/w (+backward) in bf16
"""
from __future__ import annotations

import math

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.inputs import N_MICRO

TP = 16  # model-axis size in the production meshes


def _dp(n_chips: int) -> int:
    return n_chips // TP


def _ceil_div(a, b):
    return -(-a // b)


def _shard(n, ways):
    """Padded shard size (GSPMD uneven sharding)."""
    return _ceil_div(n, ways)


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
              *, variant: str = "mbprox", flash: bool = False,
              inner_passes: int = 1) -> dict:
    """Per-chip HBM bytes for one step; returns component breakdown."""
    tp = 1 if cfg.parallelism == "dp_only" else TP
    dp = n_chips // tp
    n_micro_eff = 1 if cfg.parallelism == "dp_only" else N_MICRO
    P = cfg.param_count()
    p_bytes_dev = 2 * P / tp                   # bf16 compute copy per device
    fsdp = cfg.name in ("llama4-maverick-400b-a17b", "grok-1-314b")
    if fsdp:
        master_dev = P * 2 / (tp * dp)         # bf16 masters, FSDP
    else:
        master_dev = P * {"float32": 4, "bfloat16": 2}[cfg.param_dtype] / tp

    D, V = cfg.d_model, cfg.vocab_size
    H_loc = _shard(cfg.n_heads, tp)
    KV_loc = _shard(cfg.n_kv_heads, tp) if cfg.n_kv_heads > 1 \
        else cfg.n_kv_heads
    hd = cfg.head_dim
    L = cfg.n_layers
    n_attn_layers = (cfg.block_pattern.count("attn")
                     + cfg.block_pattern.count("moe")) * cfg.n_super \
        + sum(k in ("attn", "moe") for k in cfg.prefix_pattern)
    n_local_attn = cfg.block_pattern.count("attn_local") * cfg.n_super \
        + cfg.prefix_pattern.count("attn_local")

    comp = {}
    if shape.kind == "train":
        n_inner = n_micro_eff * inner_passes
        tokens_loc = shape.global_batch * shape.seq_len / dp / n_micro_eff
        comp["weights"] = 3.0 * p_bytes_dev * n_inner
        comp["optimizer"] = 4.0 * master_dev
        comp["activations"] = 12.0 * L * tokens_loc * D * 2 * n_inner
        if not flash:
            S = shape.seq_len
            B_loc = shape.global_batch / dp / n_micro_eff
            attn = 3.0 * B_loc * H_loc * S * S * 4
            comp["attention_scores"] = (attn * n_attn_layers
                                        + attn * (cfg.window / S)
                                        * n_local_attn) * n_inner
        comp["logits"] = 4.0 * tokens_loc * _shard(V, tp) * 2 * n_inner
        if cfg.n_experts:
            n_moe = cfg.block_pattern.count("moe") * cfg.n_super
            cap = cfg.capacity_factor * cfg.experts_per_token
            comp["moe_dispatch"] = (6.0 * tokens_loc * D * 2 * cap * n_moe
                                    * n_inner)
    elif shape.kind == "prefill":
        tokens_loc = shape.global_batch * shape.seq_len / dp
        comp["weights"] = p_bytes_dev
        comp["activations"] = 6.0 * L * tokens_loc * D * 2
        S = shape.seq_len
        B_loc = shape.global_batch / dp
        if not flash:
            attn = 3.0 * B_loc * H_loc * S * S * 4
            comp["attention_scores"] = (attn * n_attn_layers
                                        + attn * (cfg.window / S)
                                        * n_local_attn)
            # chunked path re-reads K/V per query chunk
            n_chunks = _ceil_div(S, cfg.attn_chunk)
            comp["kv_reread"] = (n_chunks * B_loc * S * KV_loc * hd * 2 * 2
                                 * n_attn_layers)
        comp["logits"] = 2.0 * tokens_loc * _shard(V, tp) * 2
        if cfg.n_experts:
            n_moe = cfg.block_pattern.count("moe") * cfg.n_super
            cap = cfg.capacity_factor * cfg.experts_per_token
            comp["moe_dispatch"] = 6.0 * tokens_loc * D * 2 * cap * n_moe
    else:  # decode
        comp["weights"] = p_bytes_dev
        B_loc = _shard(shape.global_batch, dp)
        S = shape.seq_len
        kv_bytes = (2 * B_loc * min(S, 10**9) * KV_loc * hd * 2
                    * n_attn_layers)
        kv_bytes += (2 * B_loc * min(cfg.window, S) * KV_loc * hd * 2
                     * n_local_attn)
        comp["kv_cache"] = kv_bytes
        # recurrent state r/w
        n_rwkv = cfg.block_pattern.count("rwkv") * cfg.n_super
        n_rec = (cfg.block_pattern.count("rec") * cfg.n_super
                 + cfg.prefix_pattern.count("rec"))
        comp["state"] = (2 * B_loc * cfg.n_heads * hd * hd * 4 * n_rwkv
                         + 2 * B_loc * cfg.rnn_width * 4 * n_rec)
        comp["activations"] = 12.0 * L * B_loc * D * 2
        comp["logits"] = 2.0 * B_loc * _shard(V, tp) * 2

    comp["total"] = float(sum(comp.values()))
    return comp
