"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --optimizer mbprox --ckpt-dir /tmp/run1 [--resume]

Runs on whatever devices exist (host mesh); the same step builders power
the 512-chip dry-run. Checkpoint/restart via runtime.fault_tolerance.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_lib
from repro.launch.mesh import data_axes, make_host_mesh
from repro.models import lm
from repro.optim.optimizers import Schedule, adamw


def make_batch(cfg, stream, key, batch_size, n_micro):
    toks, targets = stream.batch(key, batch_size)
    Bm = batch_size // n_micro
    return {"tokens": toks.reshape(n_micro, Bm, -1),
            "targets": targets.reshape(n_micro, Bm, -1)}


def train(arch: str, steps: int, *, optimizer: str = "mbprox",
          batch_size: int = 8, n_micro: int = 2, seq_len: int = 64,
          lr: float = 3e-3, ckpt_dir: str | None = None,
          resume: bool = False, reduced: bool = True, log_every: int = 10,
          seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=seq_len,
                         seed=seed)

    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    if optimizer == "mbprox":
        step_fn, inner_opt, mp_cfg = steps_lib.make_mbprox_train_step(
            cfg, mesh)
        opt_state = inner_opt.init(params)
    else:
        step_fn, opt = steps_lib.make_baseline_train_step(cfg, mesh)
        opt_state = opt.init(params)
    step_fn = jax.jit(step_fn)
    sched = Schedule(peak=lr, warmup=max(5, steps // 20), total=steps)

    start = 0
    if ckpt_dir and resume:
        restored, s = ckpt_lib.restore(ckpt_dir, {"params": params,
                                                  "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = s + 1
            print(f"resumed from step {s}")

    losses = []
    t0 = time.time()
    with compat.set_mesh(mesh):
        for step in range(start, steps):
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
            batch = make_batch(cfg, stream, key, batch_size, n_micro)
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.float32(sched(step)))
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"({dt:.1f}s)", flush=True)
            if ckpt_dir and (step + 1) % 50 == 0:
                ckpt_lib.save(ckpt_dir, step, {"params": params,
                                               "opt": opt_state})
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps - 1, {"params": params,
                                            "opt": opt_state})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", default="mbprox",
                    choices=["mbprox", "baseline"])
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, optimizer=args.optimizer,
                      batch_size=args.batch_size, seq_len=args.seq_len,
                      lr=args.lr, ckpt_dir=args.ckpt_dir,
                      resume=args.resume, reduced=not args.full_config)
    print(f"final loss: {losses[-1]:.4f} "
          f"(start {losses[0]:.4f}, min {min(losses):.4f})")


if __name__ == "__main__":
    main()
