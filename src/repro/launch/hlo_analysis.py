"""HLO analysis: while-aware collective + FLOP extraction, and the
3-term roofline model.

XLA's HloCostAnalysis (and compiled.cost_analysis()) visits while-loop bodies
ONCE — for scan-over-layers / microbatch-scan programs that undercounts both
flops and collective traffic by the trip counts. We therefore parse the
post-SPMD HLO text into its computation graph, extract per-computation

  * collective result bytes by op kind (+ replica group sizes),
  * dot FLOPs (2 * prod(result_dims) * contracted_size),
  * elementwise FLOPs (1 per float result element for arithmetic /
    transcendental ops, input elements for reduce) — small for dense LM
    matmul programs but material for softmax/norm-heavy decode steps,

and propagate through call sites with while-loop trip counts (recovered from
the loop-condition constant).

Roofline factors (ring algorithms):
    all-reduce      2 (p-1)/p * bytes
    all-gather      (p-1)/p   * bytes   (bytes = full gathered result)
    reduce-scatter  (p-1)/p   * bytes
    all-to-all      (p-1)/p   * bytes / p
    collective-permute            bytes

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s
per ICI link with 2 usable links per collective ring => 100 GB/s effective.
Elementwise FLOPs run on the VPU, not the MXU: 8x128 vector lanes with an
FMA per cycle (2048 FLOP/cycle) against 4 128x128 MXUs (131072
FLOP/cycle at the same clock), so the VPU peak is modeled as 1/64 of the
MXU peak. The roofline compute term is dot/MXU + elementwise/VPU —
softmax/norm-heavy decode steps are VPU-bound and a dot-only bound
undercounts them (launch/cost_model.py models the HBM term).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12
VPU_FLOPS = PEAK_FLOPS / 64       # elementwise (vector-unit) peak
HBM_BW = 819e9
ICI_BW = 100e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_TRIP_RE = re.compile(r"known_trip_count\D+(\d+)")
_CALL_RE = re.compile(
    r"(?:body|to_apply|calls|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUP_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s+[\w\-]+\(")
# operands may carry inline types: dot(f32[128,128]{1,0} %lhs, ... %rhs)
_DOT_RE = re.compile(
    r"dot\(\s*(?:([a-z]\w*\[[\d,]*\])(?:\{[\d,]*\})?\s+)?%?([\w.\-]+)\s*,"
    r"\s*(?:[a-z]\w*\[[\d,]*\](?:\{[\d,]*\})?\s+)?%?([\w.\-]+)\s*\)(.*)$")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_RE = re.compile(
    r"=\s*(\(?[\w\[\],{}\s/*=\d]+?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

# elementwise arithmetic / transcendental opcodes: 1 FLOP per float
# result element (select/compare/convert and pure data movement are free)
_EW_OPS = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "power", "remainder", "atan2", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "logistic", "tanh",
    "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "erf",
))
_FLOAT_DTYPES = frozenset(("f64", "f32", "bf16", "f16", "f8e4m3fn",
                           "f8e5m2"))
_OPCODE_RE = re.compile(
    r"=\s*([a-z]\w*)\[([\d,]*)\]\S*\s+([a-z][\w\-]*)\(")
_REDUCE_OPERAND_RE = re.compile(r"reduce\(\s*([a-z]\w*)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(s: str):
    m = _SHAPE_RE.search(s)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Comp:
    name: str
    coll: dict                    # op -> {bytes, count, group_size}
    dot_flops: float
    ew_flops: float               # elementwise + reduce FLOPs
    whiles: list                  # (body_name, cond_name)
    calls: list                   # plain to_apply / calls / fusion names
    branches: list                # conditional branch computation sets
    max_const: int = 1            # largest int constant (trip-count guess)


def _split_computations(hlo: str):
    comps, cur, name = {}, None, None
    for line in hlo.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and ("->" in line):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    name = m.group(1)
                    cur = []
            continue
        if line.startswith("}"):
            comps[name] = cur
            cur, name = None, None
        else:
            cur.append(line)
    return comps


def _elems(dims_str: str) -> int:
    n = 1
    for d in dims_str.split(","):
        if d:
            n *= int(d)
    return n


def _analyze_comp(name: str, lines) -> _Comp:
    coll = defaultdict(lambda: {"bytes": 0, "count": 0, "group_size": 1})
    dot_flops = 0.0
    ew_flops = 0.0
    whiles, calls, branches = [], [], []
    max_const = 1
    shapes = {}  # instruction name -> result dims (first shape in the type)
    for line in lines:
        s = line.strip()
        mdef = _DEF_RE.match(s)
        if mdef:
            shapes[mdef.group(1)] = _first_shape_dims(mdef.group(2))
        for m in _CONST_RE.finditer(s):
            max_const = max(max_const, int(m.group(1)))
        if " while(" in s:
            mb = re.search(r"body=%?([\w.\-]+)", s)
            mc = re.search(r"condition=%?([\w.\-]+)", s)
            mt = _TRIP_RE.search(s)
            whiles.append((mb.group(1) if mb else None,
                           mc.group(1) if mc else None,
                           int(mt.group(1)) if mt else None))
            continue
        mbr = _BRANCH_RE.search(s)
        if mbr:
            branches.append([c.strip().lstrip("%")
                             for c in mbr.group(1).split(",")])
            continue
        mop = _OPCODE_RE.search(s)
        if mop:
            rdt, rdims, opcode = mop.groups()
            if opcode in _EW_OPS and rdt in _FLOAT_DTYPES:
                ew_flops += _elems(rdims)
            elif opcode == "reduce":
                # N-element float reduce = ~N applications of the body
                mr = _REDUCE_OPERAND_RE.search(s)
                if mr and mr.group(1) in _FLOAT_DTYPES:
                    ew_flops += _elems(mr.group(2))
        if " dot(" in s and mdef:
            md = _DOT_RE.search(s)
            if md:
                out_dims = shapes.get(mdef.group(1)) or []
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                lhs_shape = ((_first_shape_dims(md.group(1))
                              if md.group(1) else None)
                             or shapes.get(md.group(2)) or [])
                mcd = _CDIMS_RE.search(md.group(4))
                cdims = ([int(x) for x in mcd.group(1).split(",") if x]
                         if mcd else [])
                csize = 1
                for cd in cdims:
                    if cd < len(lhs_shape):
                        csize *= lhs_shape[cd]
                dot_flops += 2.0 * out_elems * csize
        if any(op in s for op in _COLL_OPS):
            mcoll = _COLL_RE.search(s)
            if mcoll:
                nbytes = _shape_bytes(mcoll.group(1))
                op = mcoll.group(2)
                p = 1
                g = _GROUP_ITOA_RE.search(s)
                if g:
                    p = int(g.group(2))
                else:
                    gl = _GROUP_LIST_RE.search(s)
                    if gl:
                        p = len(gl.group(1).split(","))
                c = coll[op]
                c["bytes"] += nbytes
                c["count"] += 1
                c["group_size"] = max(c["group_size"], p)
        for mc in _CALL_RE.finditer(s):
            if not s[mc.start():].startswith(("body", "condition")):
                calls.append(mc.group(1))
    return _Comp(name, {k: dict(v) for k, v in coll.items()}, dot_flops,
                 ew_flops, whiles, calls, branches, max_const)


def analyze_hlo(hlo_text: str, entry: str | None = None) -> dict:
    """Trip-count-weighted totals: {'collectives': {...}, 'dot_flops': f,
    'elementwise_flops': f}."""
    raw = _split_computations(hlo_text)
    comps = {n: _analyze_comp(n, ls) for n, ls in raw.items()}
    if entry is None:
        # ENTRY computation: the one never referenced by others
        referenced = set()
        for c in comps.values():
            referenced.update(x for x, _, _ in c.whiles)
            referenced.update(x for _, x, _ in c.whiles)
            referenced.update(c.calls)
            for br in c.branches:
                referenced.update(br)
        entries = [n for n in comps if n not in referenced]
        entry = entries[-1] if entries else max(
            comps, key=lambda n: len(raw[n]))

    memo = {}

    def visit(name, depth=0):
        if name not in comps or depth > 64:
            return {}, 0.0, 0.0
        if name in memo:
            return memo[name]
        memo[name] = ({}, 0.0, 0.0)  # cycle guard
        c = comps[name]
        coll = {k: dict(v) for k, v in c.coll.items()}
        flops = c.dot_flops
        ew = c.ew_flops

        def acc(sub_coll, sub_flops, sub_ew, mult):
            nonlocal flops, ew
            flops += sub_flops * mult
            ew += sub_ew * mult
            for op, st in sub_coll.items():
                dst = coll.setdefault(
                    op, {"bytes": 0, "count": 0, "group_size": 1})
                dst["bytes"] += st["bytes"] * mult
                dst["count"] += st["count"] * mult
                dst["group_size"] = max(dst["group_size"], st["group_size"])

        for body, cond, known_trips in c.whiles:
            if known_trips is not None:
                trips = known_trips
            else:
                trips = comps[cond].max_const if cond in comps else 1
            sub = visit(body, depth + 1)
            acc(sub[0], sub[1], sub[2], max(trips, 1))
        for callee in c.calls:
            sub = visit(callee, depth + 1)
            acc(sub[0], sub[1], sub[2], 1)
        for br in c.branches:
            best = ({}, 0.0, 0.0)
            for b in br:
                sub = visit(b, depth + 1)
                if sub[1] + sub[2] >= best[1] + best[2]:
                    best = sub
            acc(best[0], best[1], best[2], 1)
        memo[name] = (coll, flops, ew)
        return memo[name]

    coll, flops, ew = visit(entry)
    return {"collectives": coll, "dot_flops": flops,
            "elementwise_flops": ew, "entry": entry}


def parse_collectives(hlo_text: str) -> dict:
    return analyze_hlo(hlo_text)["collectives"]


def collective_time(stats: dict, ici_bw: float = ICI_BW) -> float:
    t = 0.0
    for op, s in stats.items():
        p = max(s["group_size"], 1)
        b = s["bytes"]
        if op == "all-reduce":
            eff = 2.0 * (p - 1) / p * b
        elif op in ("all-gather", "reduce-scatter"):
            eff = (p - 1) / p * b
        elif op == "all-to-all":
            eff = (p - 1) / p * b / p
        else:
            eff = b
        t += eff / ici_bw
    return t


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                # per-chip, trip-weighted HLO dot flops
    ew_flops: float             # per-chip elementwise (VPU) flops
    hbm_bytes: float            # per-chip analytic HBM traffic
    collective_bytes: int
    model_flops: float          # global useful flops (6ND / 2ND)
    bottleneck: str
    mfu_bound: float
    useful_ratio: float         # model_flops / (flops * n_chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(flops_per_chip: float, hbm_bytes: float, coll_stats: dict,
             n_chips: int, model_flops: float,
             ew_flops: float = 0.0) -> Roofline:
    """3-term roofline. The compute term charges dot FLOPs to the MXU
    and elementwise FLOPs to the VPU (serially — they share the issue
    pipeline), so softmax/norm-heavy programs are no longer bounded by
    their (small) matmul time alone."""
    compute_s = flops_per_chip / PEAK_FLOPS + ew_flops / VPU_FLOPS
    memory_s = hbm_bytes / HBM_BW
    coll_s = collective_time(coll_stats)
    coll_bytes = int(sum(s["bytes"] for s in coll_stats.values()))
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_time = max(max(terms.values()), 1e-30)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        flops=flops_per_chip, ew_flops=ew_flops, hbm_bytes=hbm_bytes,
        collective_bytes=coll_bytes, model_flops=model_flops,
        bottleneck=bottleneck,
        mfu_bound=model_flops / (step_time * n_chips * PEAK_FLOPS),
        useful_ratio=model_flops / max(flops_per_chip * n_chips, 1e-30))
