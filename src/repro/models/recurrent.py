"""Recurrent token mixers: RWKV6 ("Finch") and RG-LRU (RecurrentGemma).

Both support:
  * sequence form  (training / prefill): lax.scan over time (the Pallas
    chunked kernels in repro.kernels replace this on TPU; this is the oracle)
  * step form      (decode): O(1) state per token — the reason these archs
    run the long_500k cell.

RWKV6 fidelity notes: data-dependent per-channel decay through a LoRA on the
token-shifted input (the Finch hallmark) and the per-head bonus `u` are
implemented; the five-way ddlerp is reduced to a single learned static mix
per projection (documented simplification, DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import init_dense


# ----------------------------------------------------------------------------
# RWKV6 time-mix
# ----------------------------------------------------------------------------

def init_rwkv(key, d_model, n_heads, head_dim, dtype, lora_rank: int = 32):
    ks = jax.random.split(key, 10)
    dh = n_heads * head_dim
    return {
        "w_r": init_dense(ks[0], d_model, dh, dtype),
        "w_k": init_dense(ks[1], d_model, dh, dtype),
        "w_v": init_dense(ks[2], d_model, dh, dtype),
        "w_g": init_dense(ks[3], d_model, dh, dtype),
        "w_o": init_dense(ks[4], dh, d_model, dtype),
        # static token-shift mixes (one per projection r,k,v,g,w)
        "mix": (jax.random.uniform(ks[5], (5, d_model)) * 0.5).astype(dtype),
        # data-dependent decay: w_t = exp(-exp(decay_base + lora))
        "decay_base": jnp.zeros((dh,), dtype),
        "decay_A": init_dense(ks[6], d_model, lora_rank, dtype),
        "decay_B": init_dense(ks[7], lora_rank, dh, dtype, scale=0.01),
        "bonus_u": (jax.random.normal(ks[8], (n_heads, head_dim))
                    * 0.1).astype(dtype),
        "ln_scale": jnp.ones((dh,), dtype),
    }


def _rwkv_projections(params, x, x_prev, n_heads, head_dim):
    """x: (B, S, D); x_prev: (B, S, D) token-shifted input."""
    mix = params["mix"].astype(x.dtype)
    xr = x + (x_prev - x) * mix[0]
    xk = x + (x_prev - x) * mix[1]
    xv = x + (x_prev - x) * mix[2]
    xg = x + (x_prev - x) * mix[3]
    xw = x + (x_prev - x) * mix[4]
    B, S, _ = x.shape
    shp = (B, S, n_heads, head_dim)
    r = (xr @ params["w_r"]).reshape(shp)
    k = (xk @ params["w_k"]).reshape(shp)
    v = (xv @ params["w_v"]).reshape(shp)
    g = jax.nn.silu(xg @ params["w_g"])
    d = params["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw @ params["decay_A"]) @ params["decay_B"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(d)).reshape(shp)               # in (0,1), fp32
    return r, k, v, g, w


def _rwkv_group_norm(y, scale, n_heads, head_dim, eps=1e-5):
    B, S = y.shape[:2]
    yf = y.reshape(B, S, n_heads, head_dim).astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mean) * lax.rsqrt(var + eps)
    return (yf.reshape(B, S, -1) * scale.astype(jnp.float32)).astype(y.dtype)


def rwkv_seq(params, x, cfg, state=None, lengths=None,
             return_states=False):
    """Sequence form. x: (B, S, D). Returns (y, new_state) — or
    (y, new_state, snapshots) with return_states=True.

    state = {"shift": (B, D) last token, "S": (B, H, hd, hd) wkv state}.

    lengths: optional (B,) int32 true lengths for right-padded batched
    prefill. Padded steps are masked so they leave the recurrence
    untouched (decay forced to 1, k zeroed => S frozen) and the shift
    state is gathered at each row's true last token, so final states
    match an unpadded per-row run exactly. Outputs at valid positions
    are unaffected either way (padding is strictly trailing).

    return_states=True additionally returns per-step state snapshots
    {"shift": (S+1, B, D), "S": (S+1, B, H, hd, hd)} where index t is
    the state after consuming t tokens (index 0 = the input state) —
    the rollback hook for speculative decoding: a rejected draft
    restores the snapshot at its accepted length. Snapshot entries past
    a row's `lengths` are junk and must not be gathered.
    """
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    if state is None:
        state = {"shift": jnp.zeros((B, D), x.dtype),
                 "S": jnp.zeros((B, H, hd, hd), jnp.float32)}
    x_prev = jnp.concatenate([state["shift"][:, None], x[:, :-1]], axis=1)
    r, k, v, g, w = _rwkv_projections(params, x, x_prev, H, hd)
    u = params["bonus_u"].astype(jnp.float32)
    if lengths is not None:
        valid = (jnp.arange(S)[None, :] < lengths[:, None])  # (B, S)
        k = k * valid[..., None, None].astype(k.dtype)
        w = jnp.where(valid[..., None, None], w, 1.0)

    def step(Sst, inp):
        rt, kt, vt, wt = inp                             # (B,H,hd) each
        rt = rt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        kv = kt[..., :, None] * vt[..., None, :]         # (B,H,hd,hd)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, Sst + u[..., None] * kv)
        S_new = wt[..., :, None] * Sst + kv
        return S_new, ((yt, S_new) if return_states else yt)

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3).astype(jnp.float32))
    S_fin, ys = lax.scan(step, state["S"], xs)
    snaps = None
    if return_states:
        ys, S_steps = ys
        snaps = {
            "S": jnp.concatenate([state["S"][None], S_steps], axis=0),
            # state after t tokens shifts on token t-1 (t=0: input state)
            "shift": jnp.concatenate(
                [state["shift"][None], jnp.swapaxes(x, 0, 1)], axis=0),
        }
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, H * hd).astype(x.dtype)
    y = _rwkv_group_norm(y, params["ln_scale"], H, hd) * g
    out = y @ params["w_o"]
    shift = x[:, -1] if lengths is None else _last_valid(x, lengths)
    new_state = {"shift": shift, "S": S_fin}
    if return_states:
        return out, new_state, snaps
    return out, new_state


def _last_valid(x, lengths):
    """x: (B, S, D) -> (B, D) rows gathered at lengths-1 (clipped)."""
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def rwkv_step(params, x, cfg, state):
    """Single-token decode. x: (B, 1, D)."""
    y, new_state = rwkv_seq(params, x, cfg,
                            state={"shift": state["shift"],
                                   "S": state["S"]})
    return y, new_state


def init_rwkv_channel_mix(key, d_model, d_ff, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"w_k": init_dense(k1, d_model, d_ff, dtype),
            "w_v": init_dense(k2, d_ff, d_model, dtype),
            "w_r": init_dense(k3, d_model, d_model, dtype),
            "mix": (jax.random.uniform(k4, (2, d_model)) * 0.5).astype(dtype)}


def rwkv_channel_mix(params, x, shift_state=None, lengths=None,
                     return_states=False):
    """RWKV channel mix (relu^2). Returns (y, last_token); with
    `lengths` the shift state is each row's true last token.
    return_states=True also returns (S+1, B, D) per-step shift
    snapshots (index t = state after t tokens; see rwkv_seq)."""
    B, S, D = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((B, D), x.dtype)
    x_prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    mix = params["mix"].astype(x.dtype)
    xk = x + (x_prev - x) * mix[0]
    xr = x + (x_prev - x) * mix[1]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    y = jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])
    shift = x[:, -1] if lengths is None else _last_valid(x, lengths)
    if return_states:
        snaps = jnp.concatenate([shift_state[None],
                                 jnp.swapaxes(x, 0, 1)], axis=0)
        return y, shift, snaps
    return y, shift


# ----------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ----------------------------------------------------------------------------

def init_rglru_block(key, d_model, rnn_width, conv_width, dtype):
    ks = jax.random.split(key, 7)
    rd = rnn_width
    return {
        "w_in_rec": init_dense(ks[0], d_model, rd, dtype),
        "w_in_gate": init_dense(ks[1], d_model, rd, dtype),
        "conv_w": (jax.random.normal(ks[2], (conv_width, rd))
                   * (conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((rd,), dtype),
        "w_a": init_dense(ks[3], rd, rd, dtype, scale=rd**-0.5),
        "b_a": jnp.zeros((rd,), dtype),
        "w_x": init_dense(ks[4], rd, rd, dtype, scale=rd**-0.5),
        "b_x": jnp.zeros((rd,), dtype),
        # Lambda parametrized so a_t in [0.9, 0.999] at init (Griffin)
        "log_lambda": jnp.linspace(-4.323, -9.0, rd).astype(jnp.float32),
        "w_out": init_dense(ks[5], rd, d_model, dtype),
    }


_RG_C = 8.0


def _rglru_gates(params, x):
    r = jax.nn.sigmoid(x @ params["w_a"] + params["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(x @ params["w_x"] + params["b_x"]).astype(jnp.float32)
    log_a = -_RG_C * jax.nn.softplus(params["log_lambda"]) * r
    a = jnp.exp(log_a)
    gated_x = (i * x.astype(jnp.float32)) * jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-12))
    return a, gated_x


def _causal_conv1d(x, w, b, state=None, lengths=None,
                   return_history=False):
    """x: (B, S, C); w: (W, C) depthwise. state: (B, W-1, C) history.
    With `lengths`, the returned history window ends at each row's true
    last input instead of the padded end. return_history=True also
    returns the padded input stream xp = [state | x] so callers can
    slice per-step history windows (speculative-decode snapshots)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    if lengths is None:
        new_state = xp[:, -(W - 1):]
    else:
        # history = inputs at padded-coords [len, len + W - 2] (token
        # positions len-W+1 .. len-1, reaching into the prior state)
        idx = lengths[:, None] + jnp.arange(W - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    if return_history:
        return out + b, new_state, xp
    return out + b, new_state


def rglru_block_seq(params, x, cfg, state=None, lengths=None,
                    return_states=False):
    """Griffin recurrent block, sequence form. x: (B, S, D).

    lengths: optional (B,) true lengths for right-padded batched
    prefill — padded steps freeze the recurrence (a=1, gated input 0)
    so final states match an unpadded per-row run.

    return_states=True also returns per-step snapshots
    {"h": (S+1, B, rd), "conv": (S+1, B, W-1, rd)} (index t = state
    after consuming t tokens; index 0 = the input state) for
    speculative-decode rollback. Entries past `lengths` are junk."""
    B, S, D = x.shape
    rd = params["w_in_rec"].shape[1]
    W = params["conv_w"].shape[0]
    if state is None:
        state = {"h": jnp.zeros((B, rd), jnp.float32),
                 "conv": jnp.zeros((B, W - 1, rd), x.dtype)}
    branch = x @ params["w_in_rec"]
    gate = jax.nn.gelu(x @ params["w_in_gate"])
    branch, conv_state, conv_xp = _causal_conv1d(
        branch, params["conv_w"], params["conv_b"], state["conv"],
        lengths=lengths, return_history=True)
    a, gx = _rglru_gates(params, branch)
    if lengths is not None:
        valid = (jnp.arange(S)[None, :] < lengths[:, None])[..., None]
        a = jnp.where(valid, a, 1.0)
        gx = jnp.where(valid, gx, 0.0)

    def step(h, inp):
        at, gxt = inp
        h_new = at * h + gxt
        return h_new, h_new

    h_fin, hs = lax.scan(step, state["h"],
                         (a.transpose(1, 0, 2), gx.transpose(1, 0, 2)))
    y = hs.transpose(1, 0, 2).astype(x.dtype) * gate
    out = y @ params["w_out"]
    new_state = {"h": h_fin, "conv": conv_state}
    if return_states:
        snaps = {
            "h": jnp.concatenate([state["h"][None], hs], axis=0),
            # conv history after t tokens = inputs t-W+1..t-1, i.e.
            # xp[:, t : t+W-1] over the [state | x] stream
            "conv": jnp.stack([conv_xp[:, t:t + W - 1]
                               for t in range(S + 1)], axis=0),
        }
        return out, new_state, snaps
    return out, new_state
