"""Decoder LM supporting every assigned architecture via block patterns.

Layer stacking: `cfg.block_pattern` defines a *superblock* (e.g. ('rec','rec',
'attn_local') for recurrentgemma, ('attn','moe') for llama4); the model is
`prefix_pattern` unrolled layers followed by `lax.scan` over `n_super`
stacked superblocks (keeps HLO size O(1) in depth — essential for the 512-
device dry-run compiles) with `jax.checkpoint` rematerialization.

Entry points:
  init_params(key, cfg)
  train_loss(params, cfg, batch)              -> loss, metrics
  forward(params, cfg, batch)                 -> logits            (prefill)
  prefill(params, cfg, batch)                 -> logits, kv cache  (serving;
         batch may carry "lengths" for right-padded mixed-length rows)
  init_decode_state(cfg, batch, max_len)      -> state pytree
  decode_step(params, cfg, state, tokens, pos)-> logits, new state (decode)
  decode_step_paged(params, cfg, state, tokens, positions, block_tables)
      -> logits, new state    (continuous-batching decode over paged KV;
         see serving/ for slot scheduling and block allocation)
  prefill_paged(params, cfg, state, tokens, lengths, cached_lens,
                block_tables, slots)
      -> last_logits, new state   (bucketed batched prefill straight into
         paged state, skipping prefix-cached tokens; see serving/runner)
  decode_verify_paged(params, cfg, state, tokens, positions, counts,
                      block_tables)
      -> per-position logits, new state, recurrent snapshots
         (batched K-token verify forward for speculative decoding;
         commit_decode_state(cfg, state, snapshots, idx) accepts/rolls
         back recurrent slot state at each lane's accepted length)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import context as dctx
from repro.models import attention, frontends, moe as moe_lib, recurrent
from repro.models.layers import (init_embed, init_mlp, mlp, rms_norm,
                                 softmax_xent)


def _pin_block(block_params):
    """Apply in-loop sharding constraints to sliced layer weights (see
    distributed/context.py). No-op when no specs are registered."""
    specs = dctx.get_inloop_specs()
    if specs is None:
        return block_params
    return jax.lax.with_sharding_constraint(block_params, specs)


def _pin_act(h):
    """Pin activations to batch-over-data (see distributed/context.py)."""
    spec = dctx.get_activation_spec()
    if spec is None:
        return h
    return jax.lax.with_sharding_constraint(h, spec)


# ----------------------------------------------------------------------------
# Block init / apply
# ----------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(key)
    D, dt = cfg.d_model, cfg.p_dtype
    norms = {"norm1": jnp.zeros((D,), dt), "norm2": jnp.zeros((D,), dt)}
    if kind in ("attn", "attn_local"):
        return {**norms,
                "attn": attention.init_attention(
                    k1, D, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt),
                "mlp": init_mlp(k2, D, cfg.d_ff, dt, cfg.mlp_kind)}
    if kind == "moe":
        return {**norms,
                "attn": attention.init_attention(
                    k1, D, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt),
                "moe": moe_lib.init_moe(k2, D, cfg.d_ff, cfg.n_experts, dt,
                                        cfg.mlp_kind)}
    if kind == "rwkv":
        return {**norms,
                "tmix": recurrent.init_rwkv(k1, D, cfg.n_heads, cfg.head_dim,
                                            dt),
                "cmix": recurrent.init_rwkv_channel_mix(k2, D, cfg.d_ff, dt)}
    if kind == "rec":
        return {**norms,
                "rec": recurrent.init_rglru_block(k1, D, cfg.rnn_width,
                                                  cfg.conv_width, dt),
                "mlp": init_mlp(k2, D, cfg.d_ff, dt, cfg.mlp_kind)}
    raise ValueError(kind)


def _apply_block_seq(params, kind: str, x, positions, cfg: ModelConfig,
                     state=None, prefix_len: int = 0,
                     collect_kv: bool = False, lengths=None):
    """Sequence form (train / prefill). Returns (x, new_state, aux).

    collect_kv=True makes attention layers return their rope'd K/V as
    new_state (the decode-cache contents) so `prefill` can seed serving
    caches in one pass; recurrent layers already return final states.

    lengths: optional (B,) true lengths for right-padded batched
    prefill. Attention needs no masking (trailing pads are causally
    invisible to valid queries); recurrent layers freeze their state
    past each row's length so final states are exact (see recurrent.py).
    """
    aux = {}
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_state = state
    if kind in ("attn", "attn_local"):
        window = cfg.window if kind == "attn_local" else 0
        o = attention.attention_block(params["attn"], h, positions, cfg,
                                      window=window, prefix_len=prefix_len,
                                      return_kv=collect_kv)
        if collect_kv:
            o, (k_seq, v_seq) = o
            new_state = {"k": k_seq, "v": v_seq}
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
    elif kind == "moe":
        o = attention.attention_block(params["attn"], h, positions, cfg,
                                      prefix_len=prefix_len,
                                      return_kv=collect_kv)
        if collect_kv:
            o, (k_seq, v_seq) = o
            new_state = {"k": k_seq, "v": v_seq}
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        o2, aux = moe_lib.moe_block(params["moe"], h2, cfg,
                                    kind=cfg.mlp_kind)
        x = x + o2
    elif kind == "rwkv":
        st_t = None if state is None else state["tmix"]
        o, st_t = recurrent.rwkv_seq(params["tmix"], h, cfg, st_t,
                                     lengths=lengths)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        st_c = None if state is None else state["cmix"]
        o2, shift = recurrent.rwkv_channel_mix(params["cmix"], h2, st_c,
                                               lengths=lengths)
        x = x + o2
        new_state = {"tmix": st_t, "cmix": shift}
    elif kind == "rec":
        st = None if state is None else state["rec"]
        o, st = recurrent.rglru_block_seq(params["rec"], h, cfg, st,
                                          lengths=lengths)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        new_state = {"rec": st}
    else:
        raise ValueError(kind)
    return x, new_state, aux


# ----------------------------------------------------------------------------
# Model init
# ----------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 8)
    D, V, dt = cfg.d_model, cfg.vocab_size, cfg.p_dtype
    params = {}
    if cfg.frontend == "audio":
        params["embed"] = frontends.init_audio_embed(
            keys[0], cfg.n_codebooks, V, D, dt)
    else:
        params["embed"] = init_embed(keys[0], V, D, dt)
    if cfg.frontend == "vision":
        params["vision"] = frontends.init_vision_frontend(
            keys[1], cfg.vision_dim, D, dt)

    # prefix (remainder) layers: unrolled, small
    prefix = []
    for i, kind in enumerate(cfg.prefix_pattern):
        prefix.append(_init_block(jax.random.fold_in(keys[2], i), cfg, kind))
    params["prefix"] = prefix

    # stacked superblocks: one stacked pytree per pattern position
    blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        layer_keys = jax.random.split(
            jax.random.fold_in(keys[3], pi), cfg.n_super)
        blocks[f"p{pi}"] = jax.vmap(
            lambda k: _init_block(k, cfg, kind))(layer_keys)
    params["blocks"] = blocks

    params["final_norm"] = jnp.zeros((D,), dt)
    if not cfg.tie_embeddings:
        if cfg.frontend == "audio":
            params["head"] = (jax.random.normal(
                keys[4], (D, cfg.n_codebooks * V)) * D**-0.5).astype(dt)
        else:
            params["head"] = (jax.random.normal(keys[4], (D, V))
                              * D**-0.5).astype(dt)
    return params


# ----------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ----------------------------------------------------------------------------

def cast_params(params, cfg: ModelConfig):
    """Cast float params to the compute dtype (single cast at step entry;
    master copies stay in cfg.param_dtype — standard mixed precision)."""
    dt = cfg.act_dtype

    def cast(p):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dt)
        return p

    return jax.tree.map(cast, params)


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Returns (h (B,S,D), positions (B,S), prefix_len)."""
    if cfg.frontend == "vision":
        tok_emb = jnp.take(params["embed"], batch["tokens"], axis=0)
        vis_emb = frontends.vision_embed(params["vision"],
                                         batch["vision_emb"]
                                         .astype(cfg.act_dtype))
        h = jnp.concatenate([vis_emb.astype(cfg.act_dtype),
                             tok_emb.astype(cfg.act_dtype)], axis=1)
        prefix_len = vis_emb.shape[1]
    elif cfg.frontend == "audio":
        h = frontends.audio_embed(params["embed"],
                                  batch["tokens"]).astype(cfg.act_dtype)
        prefix_len = 0
    else:
        h = jnp.take(params["embed"], batch["tokens"],
                     axis=0).astype(cfg.act_dtype)
        prefix_len = 0
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return h, positions, prefix_len


def _run_blocks_seq(params, cfg: ModelConfig, h, positions, prefix_len,
                    remat: bool = True, collect_kv: bool = False,
                    lengths=None):
    """Runs prefix layers + the superblock scan. Returns (h, aux, states);
    states is the per-layer decode cache (see _apply_block_seq collect_kv)
    when collect_kv=True, else None — the scan carry/ys stay identical to
    the train path in that case."""
    aux_acc = {"moe_aux": 0.0, "moe_zloss": 0.0}

    prefix_states = []
    for p, kind in zip(params["prefix"], cfg.prefix_pattern):
        h, st, aux = _apply_block_seq(p, kind, h, positions, cfg,
                                      prefix_len=prefix_len,
                                      collect_kv=collect_kv,
                                      lengths=lengths)
        prefix_states.append(st)
        for k in aux:
            aux_acc[k] = aux_acc[k] + aux[k]

    def superblock(h, block_params):
        block_params = _pin_block(block_params)
        h = _pin_act(h)
        aux_s = {"moe_aux": jnp.zeros((), jnp.float32),
                 "moe_zloss": jnp.zeros((), jnp.float32)}
        states = {}
        for pi, kind in enumerate(cfg.block_pattern):
            h, st, aux = _apply_block_seq(block_params[f"p{pi}"], kind, h,
                                          positions, cfg,
                                          prefix_len=prefix_len,
                                          collect_kv=collect_kv,
                                          lengths=lengths)
            if collect_kv:
                states[f"p{pi}"] = st
            for k in aux:
                aux_s[k] = aux_s[k] + aux[k]
        return h, ((aux_s, states) if collect_kv else aux_s)

    if remat:
        # 'dots' saves matmul outputs so backward skips the re-forward —
        # but only dots WITHOUT batch dims (saving the (B,H,S,S) attention
        # score dots costs ~18 GB/device at 4k; measured, §Perf)
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        fn = jax.checkpoint(superblock, policy=policy)
    else:
        fn = superblock
    h, ys = lax.scan(lambda c, p: fn(c, p), h, params["blocks"])
    auxs, block_states = ys if collect_kv else (ys, None)
    for k in aux_acc:
        aux_acc[k] = aux_acc[k] + (auxs[k].sum() if k in auxs else 0.0)
    states = ({"prefix": prefix_states, "blocks": block_states}
              if collect_kv else None)
    return h, aux_acc, states


def forward(params, cfg: ModelConfig, batch, remat: bool = False):
    """Full-sequence logits (prefill). For vision inputs, logits cover the
    text region only."""
    params = cast_params(params, cfg)
    h, positions, prefix_len = _embed_inputs(params, cfg, batch)
    h, aux, _ = _run_blocks_seq(params, cfg, h, positions, prefix_len,
                                remat=remat)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "vision":
        h = h[:, prefix_len:]
    logits = _head(params, cfg, h)
    return logits, aux


def _head(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        table = params["embed"]
        if cfg.frontend == "audio":
            # (n_cb, Vc, D) -> logits (B,S,n_cb,Vc)
            return jnp.einsum("bsd,cvd->bscv", h, table)
        return h @ table.T
    head = params["head"]
    if cfg.frontend == "audio":
        B, S, D = h.shape
        return (h @ head).reshape(B, S, cfg.n_codebooks, cfg.vocab_size)
    return h @ head


def prefill(params, cfg: ModelConfig, batch):
    """Single-shot chunked prefill: one jit call over the whole prompt.

    Returns (logits (B, S, V), cache) where `cache` mirrors the
    init_decode_state layer tree: attention layers hold their rope'd
    {"k","v"} of shape (B, S, KV, hd) (stacked layers carry a leading
    n_super axis from the scan), recurrent layers hold their final states.
    serving/kv_cache.load_prefill scatters this into paged slot state.

    batch may carry "lengths" ((B,) int32 true lengths) for right-padded
    mixed-length batches: attention is exact under trailing padding
    (causal masking), recurrent layers freeze past each row's length, so
    per-row cache states and logits[b, lengths[b]-1] match an unpadded
    run. KV at padded positions is garbage — consumers must slice or
    mask by length (prefill_paged's scatter does).

    Replaces the seed's token-by-token cache priming loop: S sequential
    decode_step dispatches (each a (B,1,D) matmul) collapse into one
    chunked-causal forward with MXU-shaped matmuls.
    """
    params = cast_params(params, cfg)
    h, positions, prefix_len = _embed_inputs(params, cfg, batch)
    h, _, cache = _run_blocks_seq(params, cfg, h, positions, prefix_len,
                                  remat=False, collect_kv=True,
                                  lengths=batch.get("lengths"))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "vision":
        h = h[:, prefix_len:]
    logits = _head(params, cfg, h)
    return logits, cache


def train_loss(params, cfg: ModelConfig, batch, remat: bool = True):
    logits, aux = forward(params, cfg, batch, remat=remat)
    targets = batch["targets"]
    loss = softmax_xent(logits, targets).mean()
    total = loss + 0.01 * aux["moe_aux"] + 1e-4 * aux["moe_zloss"]
    return total, {"xent": loss, **aux}


# ----------------------------------------------------------------------------
# Decode (single-token step with per-layer state)
# ----------------------------------------------------------------------------

def _init_block_state(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype):
    if kind == "attn":
        return attention.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                       cfg.head_dim, dtype)
    if kind == "attn_local":
        return attention.init_kv_cache(batch, min(cfg.window, max_len),
                                       cfg.n_kv_heads, cfg.head_dim, dtype)
    if kind == "moe":
        return attention.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                       cfg.head_dim, dtype)
    if kind == "rwkv":
        return {"tmix": {"shift": jnp.zeros((batch, cfg.d_model), dtype),
                         "S": jnp.zeros((batch, cfg.n_heads, cfg.head_dim,
                                         cfg.head_dim), jnp.float32)},
                "cmix": jnp.zeros((batch, cfg.d_model), dtype)}
    if kind == "rec":
        return {"rec": {"h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
                        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                                           cfg.rnn_width), dtype)}}
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.act_dtype
    state = {"prefix": [
        _init_block_state(cfg, kind, batch, max_len, dt)
        for kind in cfg.prefix_pattern]}
    blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        one = _init_block_state(cfg, kind, batch, max_len, dt)
        blocks[f"p{pi}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_super,) + x.shape),
            one)
    state["blocks"] = blocks
    return state


def _apply_block_step(params, kind: str, x, pos, cfg: ModelConfig, state):
    """One-token form. x: (B,1,D)."""
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        window = cfg.window if kind == "attn_local" else 0
        o, new_cache = attention.decode_attention_block(
            params["attn"], h, state, pos, cfg, window=window)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe_lib.moe_block(params["moe"], h2, cfg,
                                      kind=cfg.mlp_kind)
            x = x + o2
        else:
            x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        return x, new_cache
    if kind == "rwkv":
        o, st_t = recurrent.rwkv_seq(params["tmix"], h, cfg, state["tmix"])
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        o2, shift = recurrent.rwkv_channel_mix(params["cmix"], h2,
                                               state["cmix"])
        x = x + o2
        return x, {"tmix": st_t, "cmix": shift}
    if kind == "rec":
        o, st = recurrent.rglru_block_seq(params["rec"], h, cfg,
                                          state["rec"])
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        return x, {"rec": st}
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, state, tokens, pos):
    """tokens: (B,) int32 (or (B, n_cb) for audio); pos: scalar int32.
    Returns (logits, new_state)."""
    params = cast_params(params, cfg)
    if cfg.frontend == "audio":
        h = frontends.audio_embed(params["embed"],
                                  tokens[:, None, :]).astype(cfg.act_dtype)
    else:
        h = jnp.take(params["embed"], tokens[:, None],
                     axis=0).astype(cfg.act_dtype)

    new_prefix = []
    for p, kind, st in zip(params["prefix"], cfg.prefix_pattern,
                           state["prefix"]):
        h, st_new = _apply_block_step(p, kind, h, pos, cfg, st)
        new_prefix.append(st_new)

    def superblock(h, xs):
        block_params, block_state = xs
        block_params = _pin_block(block_params)
        h = _pin_act(h)
        new_state = {}
        for pi, kind in enumerate(cfg.block_pattern):
            h, st = _apply_block_step(block_params[f"p{pi}"], kind, h, pos,
                                      cfg, block_state[f"p{pi}"])
            new_state[f"p{pi}"] = st
        return h, new_state

    h, new_blocks = lax.scan(superblock, h,
                             (params["blocks"], state["blocks"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, h)[:, 0]
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


# ----------------------------------------------------------------------------
# Paged decode (continuous-batching serving: per-slot ragged positions)
# ----------------------------------------------------------------------------

def _apply_block_step_paged(params, kind: str, x, positions,
                            cfg: ModelConfig, state, block_tables):
    """One-token step against paged attention state. x: (B,1,D);
    positions: (B,) per-slot. Non-attention layers keep slot-indexed dense
    state (O(B) per layer) and ignore positions."""
    if kind in ("attn", "attn_local", "moe"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        window = cfg.window if kind == "attn_local" else 0
        o, new_cache = attention.paged_decode_attention_block(
            params["attn"], h, state, positions, block_tables, cfg,
            window=window)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe_lib.moe_block(params["moe"], h2, cfg,
                                      kind=cfg.mlp_kind)
            x = x + o2
        else:
            x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        return x, new_cache
    # rwkv / rec: position-independent recurrences; reuse the dense step
    return _apply_block_step(params, kind, x, 0, cfg, state)


def _apply_block_prefill_paged(params, kind: str, x, positions,
                               cfg: ModelConfig, state, block_tables,
                               starts, lengths, cached_lens, slots,
                               resume: bool = False):
    """Batched suffix-prefill against paged state. x: (N, Ls, D).

    Attention layers attend to their cached prefix through the block
    table and scatter the suffix K/V into the pools; recurrent layers
    run the length-masked sequence form and scatter final states at the
    slot indices (out-of-range slots, used for padding rows, drop).
    resume=False starts recurrent layers fresh (recurrent archs cannot
    resume from block-structured caches — the engine forces
    cached_lens = 0 for them); resume=True (a chunked-prefill
    continuation) gathers each row's initial recurrent state from its
    slot, where the previous chunk's dispatch scattered it."""
    if kind in ("attn", "attn_local", "moe"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        window = cfg.window if kind == "attn_local" else 0
        o, new_cache = attention.paged_prefill_attention_block(
            params["attn"], h, state, positions, block_tables, starts,
            lengths, cached_lens, cfg, window=window)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe_lib.moe_block(params["moe"], h2, cfg,
                                      kind=cfg.mlp_kind)
            x = x + o2
        else:
            x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        return x, new_cache
    # rwkv / rec: run over the chunk, freeze past length
    init = None
    if resume:
        num_slots = jax.tree.leaves(state)[0].shape[0]
        idx = jnp.clip(slots, 0, num_slots - 1)
        init = jax.tree.map(lambda s: s[idx], state)
    x, fin, _ = _apply_block_seq(params, kind, x, positions, cfg,
                                 state=init, lengths=lengths - starts)
    new_state = jax.tree.map(
        lambda s, c: s.at[slots].set(c.astype(s.dtype), mode="drop"),
        state, fin)
    return x, new_state


def prefill_paged(params, cfg: ModelConfig, state, tokens, lengths,
                  cached_lens, block_tables, slots, resume: bool = False):
    """Bucketed batched prefill straight into the paged serving state.

    tokens: (N, Ls) int32 — row n holds the prompt SUFFIX starting at
    min(cached_lens[n], lengths[n]-1), right-padded to the bucket length
    Ls; lengths: (N,) true prompt lengths; cached_lens: (N,) tokens
    already present in the row's blocks (prefix-cache hits — their
    compute AND their KV writes are skipped, except the last prompt
    token which is always recomputed so first-token logits exist);
    block_tables: (N, max_blocks) int32; slots: (N,) decode-slot index
    per row (recurrent dense state lands there; pass num_slots to drop,
    e.g. for batch-padding rows, which should also use lengths = 0 and
    all-null table rows). resume=True marks a chunked-prefill
    continuation: recurrent layers pick their initial state up from the
    slot instead of starting fresh (attention layers resume through
    cached_lens either way). Must be a static jit argument.

    One jitted instance serves every batch whose (N, Ls) matches — the
    scheduler buckets suffix lengths into powers of two precisely so the
    number of prefill compilations is bounded by the bucket count, not
    by the number of distinct prompt lengths in the workload.

    Returns (last_logits (N, V) at each row's true last prompt token,
    new_state).
    """
    params = cast_params(params, cfg)
    N, Ls = tokens.shape
    starts = jnp.minimum(cached_lens, jnp.maximum(lengths - 1, 0))
    positions = starts[:, None] + jnp.arange(Ls, dtype=jnp.int32)[None, :]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.act_dtype)

    new_prefix = []
    for p, kind, st in zip(params["prefix"], cfg.prefix_pattern,
                           state["prefix"]):
        h, st_new = _apply_block_prefill_paged(
            p, kind, h, positions, cfg, st, block_tables, starts, lengths,
            cached_lens, slots, resume=resume)
        new_prefix.append(st_new)

    def superblock(h, xs):
        block_params, block_state = xs
        block_params = _pin_block(block_params)
        h = _pin_act(h)
        new_state = {}
        for pi, kind in enumerate(cfg.block_pattern):
            h, st = _apply_block_prefill_paged(
                block_params[f"p{pi}"], kind, h, positions, cfg,
                block_state[f"p{pi}"], block_tables, starts, lengths,
                cached_lens, slots, resume=resume)
            new_state[f"p{pi}"] = st
        return h, new_state

    h, new_blocks = lax.scan(superblock, h,
                             (params["blocks"], state["blocks"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, h)                         # (N, Ls, V)
    idx = jnp.clip(lengths - 1 - starts, 0, Ls - 1)
    last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    return last, {"prefix": new_prefix, "blocks": new_blocks}


def _apply_block_verify_paged(params, kind: str, x, positions,
                              cfg: ModelConfig, state, block_tables,
                              starts, counts):
    """Batched K-token verify step against paged state. x: (B, T, D);
    row b holds `counts[b]` draft-chain tokens starting at absolute
    position starts[b], right-padded to the bucket length T.

    Attention layers reuse the suffix-prefill path (attend to the
    committed history through the block table + causally within the
    chain; scatter the chain's K/V — rollback is free because stale
    writes past the accepted point are position-masked and overwritten
    when those positions are re-fed). Recurrent layers resume from the
    live per-slot state, freeze past counts, and return PER-STEP state
    snapshots instead of committing: the slot state is committed later
    by commit_decode_state at each lane's accepted length. Returns
    (x, new_state, snapshots-or-None)."""
    if kind in ("attn", "attn_local", "moe"):
        x, new_state = _apply_block_prefill_paged(
            params, kind, x, positions, cfg, state, block_tables,
            starts, starts + counts, starts, None)
        return x, new_state, None
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == "rwkv":
        o, _, snap_t = recurrent.rwkv_seq(params["tmix"], h, cfg,
                                          state["tmix"], lengths=counts,
                                          return_states=True)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        o2, _, snap_c = recurrent.rwkv_channel_mix(
            params["cmix"], h2, state["cmix"], lengths=counts,
            return_states=True)
        x = x + o2
        return x, state, {"tmix": snap_t, "cmix": snap_c}
    if kind == "rec":
        o, _, snap = recurrent.rglru_block_seq(params["rec"], h, cfg,
                                               state["rec"],
                                               lengths=counts,
                                               return_states=True)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, cfg.mlp_kind)
        return x, state, {"rec": snap}
    raise ValueError(kind)


def decode_verify_paged(params, cfg: ModelConfig, state, tokens, positions,
                        counts, block_tables):
    """Batched K-token verify forward through the paged cache — the
    verify half of the propose/verify speculative-decode pipeline.

    tokens: (B, T) int32 — row b is the draft chain [pending token,
    draft_1, ..., draft_{k}] right-padded to the bucket length T;
    positions: (B,) int32 absolute position of each row's first token;
    counts: (B,) int32 true chain lengths (0 = inactive lane: nothing
    is computed or written for it); block_tables: (B, max_blocks).

    Returns (logits (B, T, V) — logits[b, i] are the next-token logits
    after consuming chain token i, exactly what decode_step_paged would
    have produced feeding the chain one token at a time —, new_state,
    snapshots). Attention K/V of all `counts` chain positions is
    scattered eagerly (stale entries from a later-rejected suffix are
    position-masked until overwritten — attention rollback is just not
    advancing the position). Recurrent slot state is NOT advanced:
    `snapshots` mirrors the recurrent layers of the state tree with
    per-step (T+1, B, ...) stacks (leading n_super axis for scanned
    blocks); commit_decode_state gathers index a+1 per lane to accept
    a draft prefix of length a, or 0 to roll back entirely.
    """
    params = cast_params(params, cfg)
    B, T = tokens.shape
    pos_grid = positions[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.act_dtype)

    new_prefix, prefix_snaps = [], []
    for p, kind, st in zip(params["prefix"], cfg.prefix_pattern,
                           state["prefix"]):
        h, st_new, snap = _apply_block_verify_paged(
            p, kind, h, pos_grid, cfg, st, block_tables, positions, counts)
        new_prefix.append(st_new)
        prefix_snaps.append(snap)

    def superblock(h, xs):
        block_params, block_state = xs
        block_params = _pin_block(block_params)
        h = _pin_act(h)
        new_state, snaps = {}, {}
        for pi, kind in enumerate(cfg.block_pattern):
            h, st, snap = _apply_block_verify_paged(
                block_params[f"p{pi}"], kind, h, pos_grid, cfg,
                block_state[f"p{pi}"], block_tables, positions, counts)
            new_state[f"p{pi}"] = st
            snaps[f"p{pi}"] = snap
        return h, (new_state, snaps)

    h, (new_blocks, block_snaps) = lax.scan(
        superblock, h, (params["blocks"], state["blocks"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, h)                          # (B, T, V)
    return (logits, {"prefix": new_prefix, "blocks": new_blocks},
            {"prefix": prefix_snaps, "blocks": block_snaps})


def commit_decode_state(cfg: ModelConfig, state, snapshots, idx):
    """Commit per-slot recurrent state after a verify step.

    snapshots: the per-step state stacks from decode_verify_paged;
    idx: (B,) int32 — tokens of lane b's chain to accept (a+1 for an
    accepted draft prefix of length a, 0 to keep the pre-verify state,
    e.g. for lanes that sat out the dispatch). Attention state needs no
    commit (positions are the rollback); recurrent leaves are gathered
    at their lane's accepted snapshot. Returns the committed state."""
    B = idx.shape[0]
    lanes = jnp.arange(B)

    def gather(snap_leaf, stacked):
        if stacked:                     # (n_super, T+1, B, ...)
            return snap_leaf[:, idx, lanes]
        return snap_leaf[idx, lanes]    # (T+1, B, ...)

    new_prefix = []
    for kind, st, snap in zip(cfg.prefix_pattern, state["prefix"],
                              snapshots["prefix"]):
        if snap is None:
            new_prefix.append(st)
        else:
            new_prefix.append(jax.tree.map(
                lambda s: gather(s, False), snap))
    new_blocks = {}
    for pi, kind in enumerate(cfg.block_pattern):
        key = f"p{pi}"
        snap = snapshots["blocks"].get(key)
        if snap is None:
            new_blocks[key] = state["blocks"][key]
        else:
            new_blocks[key] = jax.tree.map(
                lambda s: gather(s, True), snap)
    return {"prefix": new_prefix, "blocks": new_blocks}


def decode_step_paged(params, cfg: ModelConfig, state, tokens, positions,
                      block_tables):
    """One decode iteration for a slot batch. tokens: (B,) int32;
    positions: (B,) int32 per-slot token positions (ragged — slots decode
    independently); block_tables: (B, max_blocks) int32.
    Returns (logits (B, V), new_state)."""
    params = cast_params(params, cfg)
    h = jnp.take(params["embed"], tokens[:, None],
                 axis=0).astype(cfg.act_dtype)

    new_prefix = []
    for p, kind, st in zip(params["prefix"], cfg.prefix_pattern,
                           state["prefix"]):
        h, st_new = _apply_block_step_paged(p, kind, h, positions, cfg, st,
                                            block_tables)
        new_prefix.append(st_new)

    def superblock(h, xs):
        block_params, block_state = xs
        block_params = _pin_block(block_params)
        h = _pin_act(h)
        new_state = {}
        for pi, kind in enumerate(cfg.block_pattern):
            h, st = _apply_block_step_paged(block_params[f"p{pi}"], kind, h,
                                            positions, cfg,
                                            block_state[f"p{pi}"],
                                            block_tables)
            new_state[f"p{pi}"] = st
        return h, new_state

    h, new_blocks = lax.scan(superblock, h,
                             (params["blocks"], state["blocks"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, h)[:, 0]
    return logits, {"prefix": new_prefix, "blocks": new_blocks}
