"""GQA attention: chunked-causal (memory-safe long prefill), local-windowed,
and single-token KV-cache decode.

The chunked implementation scans over query chunks so peak score memory is
O(B * H * chunk * S) instead of O(B * H * S^2) — required for the 32k prefill
cells. On TPU the Pallas flash kernel (repro.kernels.flash_attention) replaces
the inner chunk computation; the jnp path here doubles as its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_rope, init_dense

NEG_INF = -1e30

# ----------------------------------------------------------------------------
# Quantized paged KV pools. Scales are per-(token slot, kv head) max-abs
# over head_dim — the optim/compression.py quantizer shape, localized per
# pool slot so each write (prefill/decode/verify) quantizes independently
# and copying a block's (q, scale) pair verbatim is an exact round-trip.
# Pool layer dicts carry "k_scale"/"v_scale" side-tables when quantized;
# consumers detect that by key presence, which is shape-static under jit.
# ----------------------------------------------------------------------------

_POOL_QMAX = {jnp.dtype(jnp.int8): 127.0}
_FP8 = getattr(jnp, "float8_e4m3fn", None)
if _FP8 is not None:
    _POOL_QMAX[jnp.dtype(_FP8)] = 448.0


def pool_qmax(dtype) -> float:
    """Max representable magnitude targeted by quantize_kv for a pool."""
    return _POOL_QMAX[jnp.dtype(dtype)]


def quantize_kv(x, dtype):
    """x: (..., KV, hd) -> (q: same shape in `dtype`, scale: (..., KV) f32).

    scale = max|x| / qmax over head_dim; q = x / max(scale, eps), rounded
    and clipped for integer pools (int8 uses the symmetric [-127, 127]
    range). All-zero slots get scale 0, so dequantize returns exact zeros.
    """
    qmax = pool_qmax(dtype)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / qmax
    q = xf / jnp.maximum(scale, 1e-12)[..., None]
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q.astype(dtype), scale


def dequantize_kv(q, scale):
    """Inverse of quantize_kv: (..., KV, hd) pool values -> float32."""
    return q.astype(jnp.float32) * scale[..., None]


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype),
    }


def _qkv(params, x, n_heads, n_kv_heads, head_dim, positions, rope_theta):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B, Sq, H, hd), k: (B, Sk, KV, hd) -> (B, H, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    q = q.reshape(B, Sq, KV, group, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, KV * group, Sq, k.shape[1])


def _gqa_out(p, v):
    """p: (B, H, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, H, hd).

    Probabilities are cast DOWN to the value dtype (not v up to f32 — that
    would materialize an f32 copy of the whole KV cache at decode); the
    matmul accumulates in f32 via preferred_element_type.
    """
    B, H, Sq, Sk = p.shape
    KV = v.shape[2]
    group = H // KV
    p = p.reshape(B, KV, group, Sq, Sk).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, -1)


def chunked_causal_attention(q, k, v, *, chunk: int = 512, window: int = 0,
                             prefix_len: int = 0):
    """Exact causal attention, scanned over query chunks.

    window > 0 => local attention (each query sees the last `window` keys).
    prefix_len > 0 => the first prefix_len positions attend bidirectionally
    (prefix-LM for the VLM arch).

    This is the training/single-shot-prefill path (attention_block's
    default; `cfg.attn_impl == "bisect"` swaps in bisect_causal_attention
    for long even sequences). The same `cfg.attn_chunk` knob also sets
    the KV band size of the serving-side streamed paged prefill
    (streamed_paged_attention / kernels/paged_prefill.py), so one config
    value bounds score-tile memory on both paths.
    """
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(S)

    def one_chunk(ci, qi):
        qpos = ci * chunk + jnp.arange(chunk)
        s = _gqa_scores(qi, k) * scale                   # (B,H,chunk,S) fp32
        causal = kpos[None, :] <= qpos[:, None]
        if prefix_len > 0:
            in_prefix = jnp.logical_and(qpos[:, None] < prefix_len,
                                        kpos[None, :] < prefix_len)
            causal = jnp.logical_or(causal, in_prefix)
        if window > 0:
            causal = jnp.logical_and(causal,
                                     kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(causal[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v)                            # (B,chunk,H,hd)

    out = lax.map(lambda args: one_chunk(*args),
                  (jnp.arange(n_chunks), qc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, H, hd)
    return out[:, :S].astype(v.dtype)


# ----------------------------------------------------------------------------
# Bisection-causal attention: static-shape causal decomposition that skips
# the strictly-upper-triangular work.  causal(S) = [causal(S/2) on A;
# merge(full(B->A), causal(S/2) on B)], recursed `depth` levels: FLOPs drop
# from S^2 to (1/2 + 1/2^{depth+1}) S^2 — the HLO-measurable analogue of the
# flash kernel's block skipping (EXPERIMENTS.md §Perf).
# ----------------------------------------------------------------------------

def _attn_stats(q, k, v, scale, causal, mask=None):
    """Unnormalized flash stats. q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd).
    `mask` (optional) is boolean, broadcastable against (B, 1, Sq, Sk)
    after the head axis is inserted — True keeps a score.
    Returns m (B,H,Sq), l (B,H,Sq), acc (B,Sq,H,hd) fp32."""
    s = _gqa_scores(q, k) * scale                       # (B,H,Sq,Sk) fp32
    if causal:
        Sq, Sk = s.shape[-2], s.shape[-1]
        cmask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(cmask[None, None], s, NEG_INF)
    if mask is not None:
        s = jnp.where(mask[:, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = _gqa_out(p, v).astype(jnp.float32)
    return m, l, acc


def _merge_stats(a, b):
    m1, l1, acc1 = a
    m2, l2, acc2 = b
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    l = a1 * l1 + a2 * l2
    # alphas are (B,H,Sq); accs are (B,Sq,H,hd)
    w1 = a1.transpose(0, 2, 1)[..., None]
    w2 = a2.transpose(0, 2, 1)[..., None]
    return m, l, w1 * acc1 + w2 * acc2


def _bisect_stats(q, k, v, scale, depth):
    S = q.shape[1]
    if depth <= 0 or S % 2 or S < 256:
        return _attn_stats(q, k, v, scale, causal=True)
    h = S // 2
    sa = _bisect_stats(q[:, :h], k[:, :h], v[:, :h], scale, depth - 1)
    sbd = _bisect_stats(q[:, h:], k[:, h:], v[:, h:], scale, depth - 1)
    sbr = _attn_stats(q[:, h:], k[:, :h], v[:, :h], scale, causal=False)
    sb = _merge_stats(sbd, sbr)
    m = jnp.concatenate([sa[0], sb[0]], axis=-1)
    l = jnp.concatenate([sa[1], sb[1]], axis=-1)
    acc = jnp.concatenate([sa[2], sb[2]], axis=1)
    return m, l, acc


def bisect_causal_attention(q, k, v, *, depth: int = 3):
    """Exact causal attention with ~(0.5 + 2^-(depth+1)) S^2 FLOPs."""
    hd = q.shape[-1]
    m, l, acc = _bisect_stats(q, k, v, hd**-0.5, depth)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(v.dtype)


def attention_block(params, x, positions, cfg, *, window: int = 0,
                    prefix_len: int = 0, return_kv: bool = False):
    """Full attention sub-layer (projections + chunked attention).

    return_kv=True also returns the rope'd (k, v) — exactly what the decode
    cache stores — so single-shot prefill can seed serving KV caches."""
    B, S, D = x.shape
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                   positions, cfg.rope_theta)
    if (cfg.attn_impl == "bisect" and window == 0 and prefix_len == 0
            and S % 2 == 0 and S >= 512):
        o = bisect_causal_attention(q, k, v)
    else:
        o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk,
                                     window=window, prefix_len=prefix_len)
    out = o.reshape(B, S, -1) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ----------------------------------------------------------------------------
# KV-cache decode
# ----------------------------------------------------------------------------

def init_kv_cache(batch, max_len, n_kv_heads, head_dim, dtype, n_super=None):
    shape = (batch, max_len, n_kv_heads, head_dim)
    if n_super is not None:
        shape = (n_super,) + shape
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention_block(params, x, cache, pos, cfg, *, window: int = 0):
    """One-token decode. x: (B, 1, D); cache k/v: (B, S_max, KV, hd);
    pos: scalar int32 current position. Returns (out, new_cache).

    For window > 0 the cache is a rolling buffer of size `window`.
    """
    B, _, D = x.shape
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                   positions, cfg.rope_theta)
    S_max = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % jnp.maximum(window, 1), pos)
    slot = jnp.asarray(slot, jnp.int32)
    ck = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    s = _gqa_scores(q, ck) * (cfg.head_dim ** -0.5)      # (B,H,1,S_max)
    kpos = jnp.arange(S_max)
    if window > 0:
        # rolling buffer: valid slots are those already written
        written = jnp.minimum(pos + 1, S_max)
        valid = kpos < written
    else:
        valid = kpos <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, cv).reshape(B, 1, -1)
    return (o @ params["wo"]).astype(x.dtype), {"k": ck, "v": cv}


# ----------------------------------------------------------------------------
# Paged KV-cache prefill (bucketed batched admission over cached prefixes)
# ----------------------------------------------------------------------------

def streamed_paged_attention(q, k, v, cache, block_tables, positions,
                             starts, lengths, *, scale, attn_chunk,
                             window: int = 0):
    """Online-softmax suffix-prefill attention over a paged KV cache.

    q: (N, Ls, H, hd) rope'd suffix queries; k/v: (N, Ls, KV, hd) rope'd
    suffix keys/values (NOT yet scattered into the pools); cache k/v:
    (P, bs, KV, hd) physical block pools holding the cached prefix;
    block_tables: (N, M); positions: (N, Ls) absolute query positions;
    starts/lengths: (N,) — queries attend to pool positions < starts and
    causally within the suffix (suffix index < lengths - starts).

    The pool is streamed in bands of ceil(attn_chunk / bs) blocks via
    lax.scan, folding each band into flash running stats (_attn_stats /
    _merge_stats), so peak score memory is O(N*H*Ls*(attn_chunk + Ls))
    — never the full O(N*H*Ls*(M*bs + Ls)) dense tensor. Doubles as the
    interpret-mode oracle for kernels/paged_prefill.py.

    Returns the normalized attention output (N, Ls, H, hd) float32.
    """
    N, Ls, H, hd = q.shape
    bs = cache["k"].shape[1]
    M = block_tables.shape[1]

    # suffix: fresh q vs fresh k/v, causal within the suffix window
    i = jnp.arange(Ls)
    causal = (i[None, :] <= i[:, None])[None]                # (1, Ls, Ls)
    in_suffix = (i[None, None, :]
                 < (lengths - starts)[:, None, None])        # (N, 1, Ls)
    valid_suf = jnp.logical_and(causal, in_suffix)           # (N, Ls, Ls)
    if window > 0:
        valid_suf = jnp.logical_and(
            valid_suf, positions[:, None, :]
            > positions[:, :, None] - window)
    suf = _attn_stats(q, k, v, scale, causal=False, mask=valid_suf)

    # cached prefix: stream the block table in fixed-size bands
    cb = max(1, -(-min(attn_chunk, M * bs) // bs))           # blocks/band
    nb = -(-M // cb)
    bt = block_tables
    if nb * cb > M:   # pad with null blocks (masked: kpos >= starts)
        bt = jnp.pad(bt, ((0, 0), (0, nb * cb - M)))
    bt = bt.reshape(N, nb, cb).transpose(1, 0, 2)            # (nb, N, cb)

    quant = "k_scale" in cache

    def band(stats, inp):
        bi, btc = inp                                        # btc: (N, cb)
        gk = cache["k"][btc].reshape(N, cb * bs, *cache["k"].shape[2:])
        gv = cache["v"][btc].reshape(N, cb * bs, *cache["v"].shape[2:])
        if quant:
            gk = dequantize_kv(
                gk, cache["k_scale"][btc].reshape(N, cb * bs, -1))
            gv = dequantize_kv(
                gv, cache["v_scale"][btc].reshape(N, cb * bs, -1))
        kpos = bi * cb * bs + jnp.arange(cb * bs)
        m = (kpos[None, None, :] < starts[:, None, None])    # (N, 1, cb*bs)
        if window > 0:
            m = jnp.logical_and(
                m, kpos[None, None, :] > positions[:, :, None] - window)
        st = _attn_stats(q, gk, gv, scale, causal=False, mask=m)
        return _merge_stats(stats, st), None

    init = (jnp.full((N, H, Ls), NEG_INF, jnp.float32),
            jnp.zeros((N, H, Ls), jnp.float32),
            jnp.zeros((N, Ls, H, hd), jnp.float32))
    pre, _ = lax.scan(band, init, (jnp.arange(nb), bt))
    m, l, acc = _merge_stats(pre, suf)
    return acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]


def paged_prefill_attention_block(params, x, cache, positions, block_tables,
                                  starts, lengths, cached_lens, cfg, *,
                                  window: int = 0):
    """Suffix prefill for a batch of sequences straight into paged KV.

    x: (N, Ls, D) — each row is one sequence's prompt SUFFIX (tokens from
    `starts[n]` on), right-padded to the bucket length Ls;
    positions: (N, Ls) absolute token positions (= starts[:, None] + i);
    starts: (N,) first computed position (cached prefix skipped, capped
    at lengths-1 so at least one token is always computed);
    lengths: (N,) true prompt lengths; cached_lens: (N,) tokens whose KV
    already sits in the sequence's blocks (scatter skips them);
    block_tables: (N, max_blocks); cache k/v: physical block pools.

    Queries attend to the cached prefix (streamed through the block
    table, masked to kpos < starts) plus the suffix causally; the
    suffix's rope'd K/V is scattered into (table[p // bs], p % bs) for
    cached_lens <= p < lengths — padded and already-cached positions are
    redirected to the null block.

    The cached prefix is NOT gathered densely: a lax.scan walks the
    block table in bands of ceil(attn_chunk / bs) blocks, folding each
    band into flash-style online-softmax running stats (_attn_stats /
    _merge_stats — the same machinery bisect_causal_attention uses), so
    peak score memory is O(N * H * Ls * (attn_chunk + Ls)) instead of
    O(N * H * Ls * (M*bs + Ls)). This is the interpret-mode oracle for
    kernels/paged_prefill.py. Returns (out, new_cache).
    """
    N, Ls, D = x.shape
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                   positions, cfg.rope_theta)
    bs = cache["k"].shape[1]
    M = block_tables.shape[1]
    o = streamed_paged_attention(q, k, v, cache, block_tables, positions,
                                 starts, lengths, scale=cfg.head_dim ** -0.5,
                                 attn_chunk=cfg.attn_chunk, window=window)
    out = (o.reshape(N, Ls, -1) @ params["wo"]).astype(x.dtype)

    write = jnp.logical_and(positions >= cached_lens[:, None],
                            positions < lengths[:, None])    # (N, Ls)
    blk = jnp.take_along_axis(block_tables,
                              jnp.clip(positions // bs, 0, M - 1), axis=1)
    blk = jnp.where(write, blk, 0)               # null-sink the rest
    off = positions % bs
    new_cache = dict(cache)
    if "k_scale" in cache:                       # quantize on landing
        k, sk = quantize_kv(k, cache["k"].dtype)
        v, sv = quantize_kv(v, cache["v"].dtype)
        new_cache["k_scale"] = cache["k_scale"].at[blk, off].set(sk)
        new_cache["v_scale"] = cache["v_scale"].at[blk, off].set(sv)
    new_cache["k"] = cache["k"].at[blk, off].set(k)
    new_cache["v"] = cache["v"].at[blk, off].set(v)
    return out, new_cache


# ----------------------------------------------------------------------------
# Paged KV-cache decode (continuous-batching serving)
# ----------------------------------------------------------------------------

def paged_decode_attention_block(params, x, cache, positions, block_tables,
                                 cfg, *, window: int = 0):
    """One-token decode through a paged KV cache (serving/kv_cache.py).

    x: (B, 1, D) — one token per slot, B = number of decode slots;
    cache k/v: (num_blocks, block_size, KV, hd) physical block pools shared
    by all slots; positions: (B,) int32 per-slot token positions (ragged —
    each slot is at its own depth); block_tables: (B, max_blocks) int32.

    The current token's K/V is scattered into (block_tables[b, p//bs],
    p % bs); scores are gathered back through the table. Slots whose table
    rows point at the reserved null block write garbage there and mask it
    out — inactive slots cost nothing but the batch lane.

    window > 0 masks to the trailing `window` positions (local attention
    keeps the full paged history; the mask, not a rolling buffer, bounds
    the receptive field). This is the pure-jnp oracle for
    kernels/paged_attention.py. Returns (out, new_cache).
    """
    B, _, D = x.shape
    q, k, v = _qkv(params, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                   positions[:, None], cfg.rope_theta)
    bs = cache["k"].shape[1]
    blk = block_tables[jnp.arange(B), positions // bs]
    off = positions % bs
    new_cache = dict(cache)
    kw, vw = k[:, 0], v[:, 0]
    if "k_scale" in cache:                       # quantize on landing
        kw, sk = quantize_kv(kw, cache["k"].dtype)
        vw, sv = quantize_kv(vw, cache["v"].dtype)
        new_cache["k_scale"] = cache["k_scale"].at[blk, off].set(sk)
        new_cache["v_scale"] = cache["v_scale"].at[blk, off].set(sv)
    ck = cache["k"].at[blk, off].set(kw)
    cv = cache["v"].at[blk, off].set(vw)

    gk = ck[block_tables].reshape(B, -1, *ck.shape[2:])  # (B, M*bs, KV, hd)
    gv = cv[block_tables].reshape(B, -1, *cv.shape[2:])
    if "k_scale" in cache:                       # dequantize the gather
        gk = dequantize_kv(gk, new_cache["k_scale"][block_tables]
                           .reshape(B, -1, ck.shape[2]))
        gv = dequantize_kv(gv, new_cache["v_scale"][block_tables]
                           .reshape(B, -1, cv.shape[2]))
    s = _gqa_scores(q, gk) * (cfg.head_dim ** -0.5)      # (B, H, 1, M*bs)
    kpos = jnp.arange(gk.shape[1])
    valid = kpos[None, :] <= positions[:, None]
    if window > 0:
        valid = jnp.logical_and(valid,
                                kpos[None, :] > positions[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, gv).reshape(B, 1, -1)
    new_cache["k"], new_cache["v"] = ck, cv
    return (o @ params["wo"]).astype(x.dtype), new_cache
