"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions:
  * params are plain dicts of jnp arrays; layer-stacked leaves carry a leading
    `n_super` axis consumed by lax.scan in lm.py.
  * activations run in cfg.dtype (bf16 by default), softmax/norms in fp32.
  * no framework dependency (flax/haiku) — keeps sharding rules transparent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": init_dense(k1, d_model, d_ff, dtype),
                "w_up": init_dense(k2, d_model, d_ff, dtype),
                "w_down": init_dense(k3, d_ff, d_model, dtype)}
    if kind == "gelu":
        return {"w_up": init_dense(k1, d_model, d_ff, dtype),
                "w_down": init_dense(k2, d_ff, d_model, dtype)}
    raise ValueError(kind)


def mlp(params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(kind)
    return h @ params["w_down"]


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def init_embed(key, vocab, d_model, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def softmax_xent(logits, targets, z_loss: float = 0.0):
    """Stable cross-entropy in fp32. logits (..., V), targets (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss > 0.0:
        loss = loss + z_loss * lse**2
    return loss
