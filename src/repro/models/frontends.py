"""Modality frontend STUBS (per assignment brief).

The VLM / audio architectures specify the transformer backbone only; the
frontend is a stub whose job is to map precomputed frontend outputs into the
backbone's embedding space:

  * vision (paligemma): `input_specs()` provides precomputed SigLIP patch
    embeddings (B, n_patches, vision_dim); here we only project them to
    d_model. The SigLIP tower itself is NOT implemented (stub).
  * audio (musicgen): `input_specs()` provides EnCodec codebook token ids
    (B, S, n_codebooks); here we sum per-codebook embeddings (the delay
    pattern is treated as preapplied by the tokenizer stub).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, init_embed


def init_vision_frontend(key, vision_dim, d_model, dtype):
    return {"proj": init_dense(key, vision_dim, d_model, dtype)}


def vision_embed(params, vision_emb):
    """(B, n_patches, vision_dim) -> (B, n_patches, d_model)."""
    return vision_emb @ params["proj"]


def init_audio_embed(key, n_codebooks, vocab, d_model, dtype):
    keys = jax.random.split(key, n_codebooks)
    return jnp.stack([init_embed(k, vocab, d_model, dtype) for k in keys])


def audio_embed(codebook_embeds, tokens):
    """codebook_embeds: (n_cb, Vc, D); tokens: (B, S, n_cb) -> (B, S, D)."""
    n_cb = codebook_embeds.shape[0]
    embs = jnp.stack([codebook_embeds[c][tokens[..., c]]
                      for c in range(n_cb)])           # (n_cb, B, S, D)
    return embs.sum(0)
