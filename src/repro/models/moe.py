"""Top-k routed Mixture-of-Experts with static capacity dispatch.

Classic dispatch/combine formulation (Mesh-TF / GShard style) chosen because
it is fully static-shaped (compiles under pjit for any mesh) and the dispatch
one-hots shard cleanly: experts over the 'model' axis (EP), tokens over
'data'. The dispatch tensors are built per *sequence chunk* (scan) so their
transient footprint is O(chunk * E * C), not O(S * E * C) — required for the
128-expert llama4 cells at 32k.

Aux losses: load-balancing loss (Switch) + router z-loss, returned to the
caller for logging / the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import context as dctx
from repro.models.layers import init_dense


def init_moe(key, d_model, d_ff, n_experts, dtype, kind: str = "swiglu"):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E = n_experts
    p = {"router": init_dense(kr, d_model, E, jnp.float32),
         "w_up": (jax.random.normal(k2, (E, d_model, d_ff))
                  * d_model**-0.5).astype(dtype),
         "w_down": (jax.random.normal(k3, (E, d_ff, d_model))
                    * d_ff**-0.5).astype(dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (E, d_model, d_ff))
                       * d_model**-0.5).astype(dtype)
    return p


def _route_topk(probs, k, capacity):
    """probs: (N, E). Returns (slots (k, N) int32 in [0, E*C] where E*C is
    the overflow slot, gates (k, N) f32, per-expert routed fraction (E,)).

    Scatter-based routing: instead of (N, E, C) one-hot dispatch tensors
    (whose einsums cost O(N * E * C * D) = O(N^2 * cf * k * D) — dominated
    grok/llama4 train compute), tokens get flat slot ids expert*C + pos and
    are moved with scatter/gather (pure data movement, zero matmul FLOPs).
    """
    N, E = probs.shape
    g = probs
    idxs, gate_list = [], []
    for _ in range(k):
        idx = jnp.argmax(g, axis=-1)                      # (N,)
        gate_list.append(jnp.take_along_axis(g, idx[:, None], -1)[:, 0])
        oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)
        idxs.append(idx)
        g = g * (1 - oh)                                  # mask chosen expert
    # CAUSAL slot assignment: token-major interleaving of the k rounds so a
    # token's slots depend only on tokens <= it (a shared per-round fill
    # counter lets FUTURE tokens' round-1 choices displace PAST tokens'
    # round-2 slots — caught by tests/test_model_invariants.py).
    idx_tok_major = jnp.stack(idxs, axis=1).reshape(N * k)     # (N*k,)
    oh_all = jax.nn.one_hot(idx_tok_major, E, dtype=jnp.int32)
    pos_all = jnp.cumsum(oh_all, axis=0) - 1                   # (N*k, E)
    pos = jnp.take_along_axis(pos_all, idx_tok_major[:, None],
                              -1)[:, 0]                        # (N*k,)
    ok_all = pos < capacity
    slot_all = jnp.where(ok_all, idx_tok_major * capacity + pos,
                         E * capacity).astype(jnp.int32)
    slots = slot_all.reshape(N, k).T                           # (k, N)
    ok = ok_all.reshape(N, k).T
    gates = jnp.stack(gate_list) * ok.astype(probs.dtype)
    routed = (oh_all * ok_all[:, None]).sum(0).astype(jnp.float32)
    return slots, gates, routed / N


def moe_block(params, x, cfg, *, kind: str = "swiglu"):
    """x: (B, S, D) -> (B, S, D), aux dict. Chunked over S.

    GROUPED dispatch (GShard): capacity slots are assigned per batch element
    (group), so the dispatch/combine einsums carry the group dim and every
    contraction is LOCAL to the data shard that owns the group — without
    grouping, the `nec,nd->ecd` contraction runs over the data-sharded token
    dim and GSPMD all-reduces (E, C, D) expert inputs across the data axis
    (observed: 18.7 TB/step on grok-1 train — see EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    E = cfg.n_experts
    k = cfg.experts_per_token
    gather_specs = dctx.get_moe_gather_specs()
    if gather_specs is not None:
        # hoist the FSDP gather of expert weights out of the chunk loop
        params = dict(params)
        for key in ("w_gate", "w_up", "w_down"):
            if key in params and key in gather_specs:
                params[key] = jax.lax.with_sharding_constraint(
                    params[key], gather_specs[key])
    chunk = min(cfg.moe_chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    capacity = max(1, int(chunk * k * cfg.capacity_factor / E))

    grouped_route = jax.vmap(_route_topk, in_axes=(0, None, None))

    def one_chunk(xi):
        # xi: (B, chunk, D); group dim = B (sharded over data).
        # One-hot dispatch einsums (GShard): scatter/gather routing was
        # tried and REJECTED — XLA SPMD partitions the scatters into dense
        # rewrites (compute x7.7, all-gather 31.9 TB on grok; §Perf it. 6).
        n = xi.shape[1]
        logits = (xi.astype(jnp.float32)
                  @ params["router"].astype(jnp.float32))    # (B, n, E)
        probs = jax.nn.softmax(logits, axis=-1)
        slots, gates, routed = grouped_route(probs, k, capacity)
        aux = E * jnp.sum(routed.mean(0) * probs.mean((0, 1)))
        zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        # build (B, n, E*C) one-hot dispatch from flat slot ids
        # slots/gates: (B, k, n) after the vmap over groups
        slot_oh = jax.nn.one_hot(slots, E * capacity,
                                 dtype=xi.dtype)             # (B, k, n, EC)
        dispatch = slot_oh.sum(1)                            # (B, n, EC)
        combine = (slot_oh
                   * gates[..., None].astype(xi.dtype)).sum(1)
        xe = jnp.einsum("gns,gnd->gsd", dispatch, xi)
        xe = xe.reshape(B, E, capacity, D)
        xe_spec = dctx.get_moe_xe_spec()
        if xe_spec is not None:
            # weight-stationary EP: reshard routed tokens (MBs) to the
            # experts instead of FSDP-gathering expert weights (GBs)
            xe = jax.lax.with_sharding_constraint(xe, xe_spec)
        if "w_gate" in params:
            h = jax.nn.silu(
                jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
            h = h * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        else:
            h = jax.nn.gelu(
                jnp.einsum("gecd,edf->gecf", xe, params["w_up"]))
        ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
        y = jnp.einsum("gns,gsd->gnd", combine,
                       ye.reshape(B, E * capacity, D))
        return y, aux, zloss

    ys, auxs, zs = lax.map(one_chunk, xc)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, D)[:, :S]
    return y, {"moe_aux": auxs.mean(), "moe_zloss": zs.mean()}
