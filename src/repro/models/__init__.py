# Import submodules directly (e.g. `from repro.models import lm`); the
# package init stays empty to avoid import cycles with configs/.
