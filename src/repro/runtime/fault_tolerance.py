"""Fault tolerance for 1000+ node minibatch-prox training.

Three mechanisms, all exploiting properties of the paper's algorithm:

1. **Checkpoint/restart** — training state is (params, inner-opt, step, rng).
   Minibatches are redrawn from the seeded stream keyed by the outer step,
   so a restarted job re-samples the SAME minibatch for the interrupted
   outer step (exactly-once semantics) and NO data-pipeline state exists to
   recover. `RestartableLoop` wraps any step function with periodic async
   checkpoints and resume.

2. **Straggler mitigation via bounded inexactness** — inner solves use a
   FIXED step budget rather than a convergence test, so a slow worker
   truncates its local solve instead of blocking the sync point. Theorem 7
   quantifies the tolerable suboptimality eta_t; `eta_budget` exposes it so
   deployments can size the step budget.

3. **Failure-domain simulation** — `FailureInjector` kills steps with a
   given probability (used by tests to prove restart converges to the same
   result as an uninterrupted run).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import theory


@dataclasses.dataclass
class FailureInjector:
    """Deterministic pseudo-random step failures for FT tests."""
    prob: float = 0.0
    seed: int = 0
    _rng: object = None

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def maybe_fail(self, step: int):
        if self.prob > 0 and self._rng.random() < self.prob:
            raise RuntimeError(f"injected failure at step {step}")


class RestartableLoop:
    """Checkpointed training loop: run(state) resumes from the latest
    checkpoint and survives (simulated or real) step failures."""

    def __init__(self, ckpt_dir: str, step_fn: Callable,
                 ckpt_every: int = 10,
                 injector: Optional[FailureInjector] = None,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn      # (state, step) -> state
        self.ckpt_every = ckpt_every
        self.injector = injector
        self.async_ckpt = (ckpt_lib.AsyncCheckpointer(ckpt_dir)
                           if async_save else None)

    def run(self, state, n_steps: int):
        restored, start = ckpt_lib.restore(self.ckpt_dir, state)
        if restored is not None:
            state, start = restored, start + 1
        else:
            start = 0
        for step in range(start, n_steps):
            if self.injector is not None:
                self.injector.maybe_fail(step)
            state = self.step_fn(state, step)
            if (step + 1) % self.ckpt_every == 0 or step == n_steps - 1:
                if self.async_ckpt is not None:
                    self.async_ckpt.save(step, state)
                else:
                    ckpt_lib.save(self.ckpt_dir, step, state)
        if self.async_ckpt is not None:
            self.async_ckpt.wait()
        return state


def eta_budget(spec: theory.ProblemSpec, b: int, T: int, t: int,
               strongly_convex: bool = False) -> float:
    """Max tolerable local-solve suboptimality at outer step t (Thm 7/8) —
    the contract a straggler's truncated solve must meet."""
    if strongly_convex:
        return theory.eta_schedule_strongly_convex(spec, b, T, t)
    return theory.eta_schedule_weakly_convex(spec, b, T, t)


def straggler_safe_inner_steps(base_steps: int, time_budget_frac: float
                               ) -> int:
    """Fixed-budget truncation: a worker that has consumed its wall-clock
    budget runs this many inner steps (>=1) and still joins the average."""
    return max(1, int(base_steps * max(0.0, min(1.0, time_budget_frac))))
