"""Elastic scaling: re-mesh and re-shard state when the device count
changes between (or during) runs.

Minibatch-prox is indifferent to m changing across outer steps — the
schedules (gamma, T) are recomputed from theory.py for the new m, and the
state that must survive is only (params, anchor) — so elasticity reduces to
resharding one pytree onto the new mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.distributed import sharding as shd


def remesh_state(state, cfg, old_mesh, new_mesh):
    """Reshard (params-like pytrees) from old_mesh onto new_mesh."""
    def move(leaf, spec):
        spec = shd.sanitize_spec(spec, leaf.shape, new_mesh)
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    specs = shd.param_specs(state, cfg)
    return jax.tree.map(move, state, specs,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def rebalance_plan(n_old: int, n_new: int, b: int, T_remaining: int):
    """Recompute the outer schedule when machine count changes: keep the
    total sample budget n = b*m*T constant (paper Thm 10 parameterization).

    Returns (new_b, new_T): we hold per-machine memory b fixed and stretch/
    shrink T so b*m*T is preserved. T rounds UP — flooring silently drops
    up to n_new-1 outer steps' worth of samples whenever b*n_new does not
    divide the remaining budget (e.g. 4 machines -> 3 with b*T_remaining
    odd), and a convergence bound paid for n samples should never run on
    fewer; overshooting by a partial step keeps b*m*T >= the old budget."""
    total = b * n_old * T_remaining
    new_T = max(1, -(-total // (b * n_new)))
    return b, new_T
