"""Blocked causal GQA flash attention (online softmax), TPU Pallas.

Grid: (B, H, Sq/bq, Sk/bk) with the key axis innermost ("arbitrary"
semantics — sequential per (B,H,q-block), carrying online-softmax stats in
VMEM scratch). Causal block-skip: key blocks strictly above the diagonal
contribute nothing and are masked; with the skip the kernel performs
~S^2/2 useful MACs instead of the jnp chunked path's S^2.

BlockSpecs stage (bq, hd) query tiles and (bk, hd) K/V tiles into VMEM;
the (bq, bk) score tile never touches HBM — the memory win over the
materializing path (3*B*H*S^2*4 bytes saved; see launch/cost_model.py).

GQA: K/V indexed by kv_head = q_head // (H // KV) in the BlockSpec index
maps — no head-expansion copies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, bq, bk, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip key blocks strictly above the diagonal
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (q @ k.T) * scale                          # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    grid = (B, H, pl.cdiv(Sq, bq), pl.cdiv(Sk, bk))

    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, hd),
                           lambda b, h, i, j: (b, h // group, j, 0))
    o_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))

    kernel = functools.partial(_kernel, scale=hd**-0.5, bq=bq, bk=bk,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # m, l, acc live in VMEM across the key-block sweep
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
