"""Chunk-parallel paged suffix-prefill attention (online softmax), TPU Pallas.

Suffix prefill for serving: each row's queries are a bucket-padded prompt
suffix whose KV cache prefix lives in fixed-size physical blocks of a
shared pool, mapped through a per-row block table. Queries attend to the
cached prefix (pool positions < starts[n]) plus the fresh suffix causally
— exactly `attention.streamed_paged_attention`, which is this kernel's
interpret-mode oracle.

Grid: (N, KV, Ls/bq, M + Ls/bs) with the key axis innermost ("arbitrary"
semantics — sequential per (row, kv_head, q-tile), carrying online-softmax
stats in VMEM scratch). The first M key steps stream physical pool blocks
gathered through the scalar-prefetched block table (skipped once past the
cached prefix); the remaining Ls/bs steps stream the fresh suffix K/V
tiles (skipped strictly above the causal diagonal). Only a
(group*bq, bs) score tile ever materializes — peak score memory is
independent of both the prompt length and the block-table bound M.

GQA: queries are laid out (N, KV, group, Ls, hd); each step contracts the
whole (group, bq) query tile against one (bs, hd) K/V tile, with kv_head
indexing in the BlockSpec maps like kernels/paged_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _kernel(bt_ref, st_ref, ln_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
            *refs, scale, bs, bq, M, window, quant):
    if quant:
        kps_ref, vps_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    n = pl.program_id(0)
    qi = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    st = st_ref[n]
    ln = ln_ref[n]
    is_pool = j < M
    js = j - M                       # suffix tile index when j >= M
    # pool blocks are skipped once past the cached prefix; suffix tiles
    # strictly above the causal diagonal are skipped
    run = jnp.where(is_pool, j * bs < st, js * bs <= qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        G = m_ref.shape[0]           # group * bq rows
        q = q_ref[0, 0].astype(jnp.float32).reshape(G, -1)   # (g*bq, hd)
        kp = kp_ref[0, :, 0].astype(jnp.float32)             # (bs, hd)
        vp = vp_ref[0, :, 0].astype(jnp.float32)
        if quant:                    # dequantize the pool side in-register
            kp = kp * kps_ref[0, :, 0][:, None]
            vp = vp * vps_ref[0, :, 0][:, None]
        k = jnp.where(is_pool, kp, ks_ref[0, :, 0].astype(jnp.float32))
        v = jnp.where(is_pool, vp, vs_ref[0, :, 0].astype(jnp.float32))
        s = (q @ k.T) * scale                                # (g*bq, bs)
        qrow = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % bq
        qpos = st + qi * bq + qrow                           # absolute
        c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        kpos = jnp.where(is_pool, j * bs + c, st + js * bs + c)
        valid = jnp.where(is_pool, kpos < st,
                          jnp.logical_and(kpos <= qpos,
                                          js * bs + c < ln - st))
        if window > 0:
            valid = jnp.logical_and(valid, kpos > qpos - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        acc = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = acc.reshape(o_ref.shape[2:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "interpret"))
def paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, block_tables,
                            starts, lengths, *, k_scale=None, v_scale=None,
                            window: int = 0, bq: int = 128,
                            interpret: bool = True):
    """q: (N, Ls, H, hd) rope'd suffix queries; k_suf/v_suf: (N, Ls, KV, hd)
    fresh suffix K/V (not yet scattered into the pools); k_pool/v_pool:
    (P, bs, KV, hd) physical block pools; block_tables: (N, M) int32;
    starts/lengths: (N,) int32 (rows with lengths == 0 return garbage —
    mask downstream); k_scale/v_scale (optional): (P, bs, KV) float32
    side-tables of a quantized pool, dequantized in-kernel (the fresh
    suffix K/V stays full-precision). Returns (N, Ls, H, hd) in q.dtype."""
    N, Ls, H, hd = q.shape
    _, bs, KV, _ = k_pool.shape
    group = H // KV
    M = block_tables.shape[1]
    bq = min(bq, Ls)
    nq = pl.cdiv(Ls, bq)
    ns = pl.cdiv(Ls, bs)
    qg = q.reshape(N, Ls, KV, group, hd).transpose(0, 2, 3, 1, 4)
    quant = k_scale is not None

    def q_map(n, kv, qi, j, bt_ref, st_ref, ln_ref):
        return (n, kv, 0, qi, 0)

    def pool_map(n, kv, qi, j, bt_ref, st_ref, ln_ref):
        return (bt_ref[n, jnp.minimum(j, M - 1)], 0, kv, 0)

    def suf_map(n, kv, qi, j, bt_ref, st_ref, ln_ref):
        return (n, jnp.clip(j - M, 0, ns - 1), kv, 0)

    def sc_map(n, kv, qi, j, bt_ref, st_ref, ln_ref):
        return (bt_ref[n, jnp.minimum(j, M - 1)], 0, kv)

    in_specs = [
        pl.BlockSpec((1, 1, group, bq, hd), q_map),
        pl.BlockSpec((1, bs, 1, hd), pool_map),
        pl.BlockSpec((1, bs, 1, hd), pool_map),
        pl.BlockSpec((1, bs, 1, hd), suf_map),
        pl.BlockSpec((1, bs, 1, hd), suf_map),
    ]
    operands = [qg, k_pool, v_pool, k_suf, v_suf]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), sc_map),
                     pl.BlockSpec((1, bs, 1), sc_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(_kernel, scale=hd**-0.5, bs=bs, bq=bq,
                               M=M, window=window, quant=quant)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(N, KV, nq, M + ns),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, group, bq, hd), q_map),
            scratch_shapes=[
                # m, l, acc live in VMEM across the key sweep
                pltpu.VMEM((group * bq, 1), jnp.float32),
                pltpu.VMEM((group * bq, 1), jnp.float32),
                pltpu.VMEM((group * bq, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N, KV, group, Ls, hd), q.dtype),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(block_tables, starts, lengths, *operands)
    return out.transpose(0, 3, 1, 2, 4).reshape(N, Ls, H, hd)
