"""RWKV6 (Finch) recurrence kernel: chunked state-resident scan.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;   y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Grid (B, H, T/Tc) with the chunk axis sequential ("arbitrary"): the (N, N)
state lives in VMEM scratch across chunks — zero HBM state traffic, and
r/k/v/w stream through VMEM in (Tc, N) tiles. The jnp reference scans over
single tokens with the state in HBM every step; per token the kernel removes
2 * N*N * 4B of state traffic (N=64: 32 KB/token/head) — the dominant term
at decode/training for attention-free archs (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref, *,
            tc):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                    # (N,)

    def body(t, _):
        rt = r_ref[0, 0, t].astype(jnp.float32)         # (N,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        S = s_ref[...]
        kv = kt[:, None] * vt[None, :]                  # (N, N)
        y = rt @ (S + u[:, None] * kv)                  # (N,)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        s_ref[...] = wt[:, None] * S + kv
        return ()

    jax.lax.fori_loop(0, tc, body, ())

    @pl.when(ci == nc - 1)
    def _emit_state():
        sout_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("tc", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, tc: int = 64, interpret: bool = True):
    """r/k/v/w: (B, H, T, N); u: (H, N). Returns (y, final_state)."""
    B, H, T, N = r.shape
    tc = min(tc, T)
    grid = (B, H, pl.cdiv(T, tc))
    x_spec = pl.BlockSpec((1, 1, tc, N), lambda b, h, c: (b, h, c, 0))
    u_spec = pl.BlockSpec((1, N), lambda b, h, c: (h, 0))
    s_spec = pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0))

    kernel = functools.partial(_kernel, tc=tc)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec, u_spec],
        out_specs=(x_spec, s_spec),
        out_shape=(jax.ShapeDtypeStruct(r.shape, r.dtype),
                   jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(r, k, v, w, u)
    return y, s_fin
