"""RG-LRU gated linear recurrence kernel:  h_t = a_t * h_{t-1} + x_t.

Grid (B, C/Cb, T/Tc) with the chunk axis sequential; the (Cb,) hidden state
stays in VMEM scratch across chunks. Channels are independent, so the
channel axis is freely parallel/shardable. Same state-residency argument as
rwkv6_scan: the jnp lax.scan round-trips h (B, C) through HBM per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _kernel(a_ref, x_ref, h0_ref, y_ref, hout_ref, h_ref, *, tc):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    def body(t, _):
        at = a_ref[0, t].astype(jnp.float32)
        xt = x_ref[0, t].astype(jnp.float32)
        h = at * h_ref[...] + xt
        h_ref[...] = h
        y_ref[0, t] = h.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, tc, body, ())

    @pl.when(ci == nc - 1)
    def _emit():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("tc", "cb", "interpret"))
def rglru_scan(a, x, h0=None, *, tc: int = 128, cb: int = 256,
               interpret: bool = True):
    """a, x: (B, T, C); h0: (B, C) or None. Returns (h_seq, h_final)."""
    B, T, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    tc = min(tc, T)
    cb = min(cb, C)
    grid = (B, pl.cdiv(C, cb), pl.cdiv(T, tc))
    x_spec = pl.BlockSpec((1, tc, cb), lambda b, cj, ci: (b, ci, cj))
    h_spec = pl.BlockSpec((1, cb), lambda b, cj, ci: (b, cj))

    kernel = functools.partial(_kernel, tc=tc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, h_spec],
        out_specs=(x_spec, h_spec),
        out_shape=(jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct((B, C), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((cb,), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, x, h0)
    return y, h_fin
