"""Paged decode attention (block-table gather), TPU Pallas.

Single-token decode where each sequence's KV cache lives in fixed-size
physical blocks of a shared pool; a per-sequence block table maps logical
block j to its physical block id. The block table and per-sequence context
lengths ride in as scalar-prefetch operands so the K/V BlockSpec index maps
can gather physical blocks directly — no head-expansion or cache
defragmentation copies ever touch HBM.

Grid: (B, KV, M) with the logical-block axis innermost ("arbitrary"
semantics — sequential per (seq, kv_head), carrying online-softmax stats in
VMEM scratch). Blocks at or past the context length are skipped entirely,
so decode attention reads ceil(ctx/bs) blocks per sequence, not the
allocation bound M.

GQA: queries are laid out (B, KV, group, hd); each grid step contracts the
whole query group against one (bs, hd) K/V block — kv_head indexing happens
in the BlockSpec maps, mirroring flash_attention.py.

Quantized pools (serving/kv_cache.py kv_dtype "int8"/"fp8") pass their
per-(token slot, kv head) scale side-tables as extra operands; the kernel
dequantizes each gathered block in-register against a (1, bs, 1) scale
tile indexed by the same block-table map, so HBM still only ever moves the
narrow pool elements.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _kernel(bt_ref, cl_ref, q_ref, *refs, scale, bs, quant):
    if quant:
        k_ref, v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = cl_ref[b]

    @pl.when(j * bs < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (group, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, :, 0][:, None]             # (bs,) scales
            v = v * vs_ref[0, :, 0][:, None]
        s = (q @ k.T) * scale                            # (group, bs)
        kpos = j * bs + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < ctx, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, block_tables, ctx_lens, *,
                    k_scale=None, v_scale=None, interpret: bool = True):
    """q: (B, H, hd); k_pool/v_pool: (N, bs, KV, hd);
    block_tables: (B, M) int32; ctx_lens: (B,) int32 valid-token counts
    (rows with ctx_lens == 0 return zeros); k_scale/v_scale (optional):
    (N, bs, KV) float32 side-tables of a quantized pool — when given the
    kernel dequantizes gathered blocks in-register. Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, bs, KV, _ = k_pool.shape
    group = H // KV
    M = block_tables.shape[1]
    qg = q.reshape(B, KV, group, hd)
    quant = k_scale is not None

    def q_map(b, kv, j, bt_ref, cl_ref):
        return (b, kv, 0, 0)

    def kv_map(b, kv, j, bt_ref, cl_ref):
        return (bt_ref[b, j], 0, kv, 0)

    def sc_map(b, kv, j, bt_ref, cl_ref):
        return (bt_ref[b, j], 0, kv)

    in_specs = [
        pl.BlockSpec((1, 1, group, hd), q_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
    ]
    operands = [qg, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), sc_map),
                     pl.BlockSpec((1, bs, 1), sc_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(_kernel, scale=hd**-0.5, bs=bs, quant=quant)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, M),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, group, hd), q_map),
            scratch_shapes=[
                # m, l, acc live in VMEM across the logical-block sweep
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, group, hd), q.dtype),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(block_tables, ctx_lens, *operands)
    return out.reshape(B, H, hd)
