"""Fused variance-reduced prox update — the paper's inner-loop hot spot.

    x <- x - eta * (g_x - g_z + mu + gamma * (x - w_anchor))

Unfused, this is 5 HBM reads + 1 write with 4 intermediate round-trips;
fused it is a single pass (memory-bound, ~6x traffic reduction). Executed
n(eps)/m * log n(eps) times per training run, on parameter-sized vectors.

TPU mapping: 1D vectors are viewed as (rows, 256)-shaped tiles (lane width
aligned); BlockSpec streams (BLOCK_ROWS, 256) tiles HBM->VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 256
BLOCK_ROWS = 512  # (512, 256) f32 tile = 512 KB/operand; 6 operands ~ 3 MB


def _kernel(x_ref, gx_ref, gz_ref, mu_ref, w_ref, eta_ref, gamma_ref,
            out_ref):
    eta = eta_ref[0]
    gamma = gamma_ref[0]
    x = x_ref[...]
    g = (gx_ref[...] - gz_ref[...] + mu_ref[...]
         + gamma * (x - w_ref[...]))
    out_ref[...] = x - eta * g


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def svrg_update(x, g_x, g_z, mu, w_anchor, eta, gamma, *,
                interpret: bool = True, block_rows: int = BLOCK_ROWS):
    """All array args are 1-D of equal length; eta/gamma scalars."""
    (n,) = x.shape
    pad = (-n) % LANES
    def prep(a):
        a = jnp.pad(a, (0, pad))
        return a.reshape(-1, LANES)
    xs = [prep(a) for a in (x, g_x, g_z, mu, w_anchor)]
    rows = xs[0].shape[0]
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec(memory_space=pl.ANY)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 5 + [scalar_spec] * 2,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xs[0].shape, x.dtype),
        interpret=interpret,
    )(*xs, jnp.asarray(eta, x.dtype).reshape(1),
      jnp.asarray(gamma, x.dtype).reshape(1))
    return out.reshape(-1)[:n]
