"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each `*_ref` is the semantic definition; kernels must match it in
interpret mode (CPU tests) and on real TPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def svrg_update_ref(x, g_x, g_z, mu, w_anchor, eta, gamma):
    """One fused variance-reduced prox step (paper Alg. 1 step 2):

        x <- x - eta * (g_x - g_z + mu + gamma * (x - w_anchor))
    """
    return x - eta * (g_x - g_z + mu + gamma * (x - w_anchor))


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd). GQA via head grouping."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, group, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qf, kf) * hd**-0.5
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, ctx_lens,
                        k_scale=None, v_scale=None):
    """Single-token decode attention through a paged KV cache.

    q: (B, H, hd) current-token queries; k_pool/v_pool: (N, bs, KV, hd)
    physical blocks; block_tables: (B, M) int32 block ids per sequence;
    ctx_lens: (B,) int32 number of valid tokens (0 => output row is zeros);
    k_scale/v_scale (optional): (N, bs, KV) float32 side-tables of a
    quantized pool — pool values are dequantized after the dense gather.
    GQA via head grouping. Returns (B, H, hd).
    """
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    group = H // KV
    k = k_pool[block_tables].reshape(B, -1, KV, hd).astype(jnp.float32)
    v = v_pool[block_tables].reshape(B, -1, KV, hd).astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[block_tables].reshape(B, -1, KV)[..., None]
        v = v * v_scale[block_tables].reshape(B, -1, KV)[..., None]
    qf = q.astype(jnp.float32).reshape(B, KV, group, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k) * hd**-0.5
    valid = jnp.arange(k.shape[1])[None, :] < ctx_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v).reshape(B, H, hd)
    o = jnp.where(ctx_lens[:, None, None] > 0, o, 0.0)
    return o.astype(q.dtype)


def rwkv6_ref(r, k, v, w, u, s0=None):
    """RWKV6 recurrence. r/k/v: (B, H, T, N); w: (B, H, T, N) decays in
    (0,1); u: (H, N) bonus. Returns (out (B,H,T,N), s_T (B,H,N,N))."""
    B, H, T, N = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        yt = jnp.einsum("bhk,bhkv->bhv", rt,
                        S + u[None].astype(jnp.float32)[..., None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, yt

    xs = tuple(a.transpose(2, 0, 1, 3).astype(jnp.float32)
               for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), s_fin


def rglru_ref(a, x, h0=None):
    """Gated linear recurrence h_t = a_t * h_{t-1} + x_t.
    a, x: (B, T, C) with a in (0,1). Returns (h (B,T,C), h_T (B,C))."""
    B, T, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)

    def step(h, inp):
        at, xt = inp
        h_new = at * h + xt
        return h_new, h_new

    xs = (a.transpose(1, 0, 2).astype(jnp.float32),
          x.transpose(1, 0, 2).astype(jnp.float32))
    h_fin, hs = jax.lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2).astype(x.dtype), h_fin
