"""jit'd dispatch wrappers for all Pallas kernels.

On CPU (this container) kernels run in interpret mode (Python emulation of
the kernel body — correctness only); on TPU they compile through Mosaic.
`use_kernels(False)` forces the pure-jnp reference path (used by ablations
and the dry-run default, since Pallas does not lower on the CPU backend).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6
from repro.kernels.svrg_update import svrg_update as _svrg

_USE_KERNELS = True


def use_kernels(flag: bool) -> None:
    global _USE_KERNELS
    _USE_KERNELS = flag


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def svrg_update(x, g_x, g_z, mu, w_anchor, eta, gamma):
    if not _USE_KERNELS:
        return ref.svrg_update_ref(x, g_x, g_z, mu, w_anchor, eta, gamma)
    return _svrg(x, g_x, g_z, mu, w_anchor, eta, gamma,
                 interpret=_interpret())


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    if not _USE_KERNELS:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash(q, k, v, causal=causal, bq=bq, bk=bk,
                  interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, *, tc=64):
    if not _USE_KERNELS:
        return ref.rwkv6_ref(r, k, v, w, u)
    return _rwkv6(r, k, v, w, u, tc=tc, interpret=_interpret())


def rglru_scan(a, x, h0=None, *, tc=128, cb=256):
    if not _USE_KERNELS:
        return ref.rglru_ref(a, x, h0)
    return _rglru(a, x, h0, tc=tc, cb=cb, interpret=_interpret())
