"""rwkv6-3b — "Finch": attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=2560, d_ff=8960, vocab=65536. Head size 64 => 40 heads.
Sub-quadratic (O(1) decode state) => runs the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    mlp_kind="swiglu",        # unused by rwkv blocks (channel-mix instead)
    subquadratic=True,
)
