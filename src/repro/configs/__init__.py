"""Architecture registry: `get_config('<arch-id>')` for --arch flags."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

ARCHS = {
    "rwkv6-3b": "rwkv6_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "grok-1-314b": "grok1_314b",
    "stablelm-3b": "stablelm_3b",
    "smollm-135m": "smollm_135m",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "minitron-4b": "minitron_4b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-medium": "musicgen_medium",
    "paper-lsq": "paper_lsq",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs():
    return [a for a in ARCHS if a != "paper-lsq"]
