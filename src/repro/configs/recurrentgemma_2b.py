"""recurrentgemma-2b — RG-LRU + local attention, pattern (R,R,A)
[arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000, window=2048.
26 = 8 x (rec,rec,attn_local) + prefix (rec,rec).
Sub-quadratic decode (fixed recurrent state + 2048-window KV) => long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn_local"),
    rnn_width=2560,
    window=2048,
    mlp_kind="geglu",
    tie_embeddings=True,
    subquadratic=True,
)
