"""llama4-maverick-400b-a17b — MoE, early fusion [hf:meta-llama/Llama-4-*].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
MoE on every *second* layer (interleave step 2, matching the HF architecture
and the ~400B total / ~17B active counts — see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn", "moe"),
    n_experts=128,
    experts_per_token=1,
    mlp_kind="swiglu",
    rope_theta=500000.0,
    # memory plan (16 GB v5e): bf16 params + bf16 inner-momentum + bf16
    # anchor, FSDP over 'data' x TP over 'model' (DESIGN.md §5)
    param_dtype="bfloat16",
)
