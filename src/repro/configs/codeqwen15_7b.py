"""codeqwen1.5-7b — qwen1.5-arch dense [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    block_pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=1000000.0,
)
