"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (MHA kv=24) d_ff=6144, 4 codebooks x vocab 2048.
EnCodec frontend is a STUB: input_specs() provides codebook token ids
(B, S, 4) with the delay pattern preapplied.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    mlp_kind="gelu",
    frontend="audio",
    n_codebooks=4,
)
