"""paper-lsq — the paper's own workload: distributed stochastic least squares.

Not a transformer; `CONFIG` carries the convex-problem description consumed by
benchmarks and the quickstart example (d = feature dimension).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LsqConfig:
    name: str = "paper-lsq"
    family: str = "convex"
    dim: int = 64
    noise: float = 0.1
    decay: float = 0.5
    radius: float = 1.0


CONFIG = LsqConfig()
