"""paligemma-3b — SigLIP + gemma backbone [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
Vision frontend is a STUB: input_specs() provides precomputed SigLIP patch
embeddings (B, 256, 1152); prefix-LM attention over the vision prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    block_pattern=("attn",),
    mlp_kind="geglu",
    frontend="vision",
    vision_tokens=256,
    vision_dim=1152,
    tie_embeddings=True,
)
