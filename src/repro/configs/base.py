"""Config dataclasses shared by all architectures.

Every assigned architecture gets one module `src/repro/configs/<id>.py`
exporting `CONFIG: ModelConfig` (exact published sizes) — the registry in
`configs/__init__.py` resolves `--arch <id>`. `reduced()` yields the
CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    # block structure: tuple of 'attn' | 'moe' | 'rwkv' | 'rec' | 'attn_local'
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp_kind: str = "swiglu"
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # sequence-chunk for the MoE dispatch: 4096 = unchunked at train_4k
    # (chunking the train path puts one expert-grad all-reduce per chunk in
    # the backward — measured 4.6 TB/step on grok; §Perf iteration 5);
    # 32k prefill still chunks 8x, and has no backward.
    moe_chunk: int = 4096
    # recurrence
    rnn_width: int = 0
    conv_width: int = 4
    window: int = 2048             # local-attention window for 'attn_local'
    # frontends
    frontend: str = "none"         # none | vision | audio
    n_codebooks: int = 1
    vision_tokens: int = 256
    vision_dim: int = 1152
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_chunk: int = 512
    attn_impl: str = "chunked"     # chunked | bisect (perf variant)
    remat_policy: str = "full"     # full | dots (save matmul outputs)
    # 'tp': shard params over 'model' (default). 'dp_only': replicate params
    # and use the model axis as extra data parallelism — right for <1B archs
    # where 16-way TP means 36-column matmuls and per-layer psums dominate
    # (smollm measured collective-bound at mfu 0.038; §Perf).
    parallelism: str = "tp"
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "float32"
    # long-context capability: True iff sequence mixing is sub-quadratic
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.rnn_width == 0 and "rec" in self.block_pattern:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_super(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def prefix_pattern(self) -> Tuple[str, ...]:
        rem = self.n_layers % self.pattern_len
        return self.block_pattern[:rem]

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2 * self.pattern_len
                         + len(self.prefix_pattern) % self.pattern_len),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            rnn_width=64 if self.rnn_width else 0,
            vision_dim=32,
            vision_tokens=8,
            window=min(self.window, 16),
            attn_chunk=16,
            moe_chunk=16,
            param_dtype="float32",
            dtype="float32",
        )
        # keep prefix-layer structure representative: n_layers =
        # 2 superblocks + original remainder
        rem = self.n_layers % self.pattern_len
        changes["n_layers"] = 2 * self.pattern_len + rem
        changes.update(overrides)
        return dataclasses.replace(self, **changes)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = 0
        emb = V * D * (self.n_codebooks if self.frontend == "audio" else 1)
        total += emb
        if not self.tie_embeddings:
            total += D * V * (self.n_codebooks
                              if self.frontend == "audio" else 1)
        if self.frontend == "vision":
            total += self.vision_dim * D

        def attn_p():
            return D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D

        def mlp_p():
            mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            return mult * D * F

        def block_p(kind):
            if kind == "attn" or kind == "attn_local":
                return attn_p() + mlp_p() + 2 * D
            if kind == "moe":
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                return attn_p() + D * self.n_experts \
                    + self.n_experts * mult * D * F + 2 * D
            if kind == "rwkv":
                dh = H * hd
                tmix = 4 * D * dh + dh * D + 64 * (D + dh) + dh
                cmix = D * F + F * D + D * D
                return tmix + cmix + 2 * D
            if kind == "rec":
                rd = self.rnn_width
                rec = 2 * D * rd + 2 * rd * rd + rd * D \
                    + self.conv_width * rd
                return rec + mlp_p() + 2 * D
            raise ValueError(kind)

        for kind in self.prefix_pattern:
            total += block_p(kind)
        for kind in self.block_pattern:
            total += self.n_super * block_p(kind)
        total += D  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        per_expert = mult * self.d_model * self.d_ff
        n_moe_layers = (self.block_pattern.count("moe") * self.n_super
                        + self.prefix_pattern.count("moe"))
        inactive = n_moe_layers * (self.n_experts
                                   - self.experts_per_token) * per_expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
