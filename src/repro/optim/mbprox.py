"""Minibatch-prox as a first-class deep-learning optimizer (the framework's
integration of the paper's technique).

One MBProx *outer step* consumes a held global minibatch of `n_micro`
microbatches and approximately solves (paper eq. 12)

    min_w  loss_minibatch(w) + (gamma/2) ||w - anchor||^2 ,

then advances the anchor. Two execution variants map it onto a TPU mesh:

  * `local` (MP-DANE form, App. D / Algorithm 2): every data shard solves the
    prox subproblem on ITS OWN shard of the minibatch with `inner_passes`
    epochs of momentum-SGD (zero data-axis collectives), then the solutions
    are averaged (eq. 34; ONE all-reduce of params). An optional DANE gradient
    correction <pmean(g) - g_local, w> costs one more all-reduce at the
    anchor. Implemented with `shard_map` manual over the data/pod axes and
    GSPMD-auto over 'model' (TP stays automatic inside).
    => data/pod-axis collectives per outer step: 1 (2 with correction),
       versus `n_micro` for the baseline. This is the paper's
       communication↔memory tradeoff realized at the training-step level.

  * `sync` (Theorem 7's generic inexact solver): inner steps are synchronous
    minibatch-SGD steps on the held minibatch (grad all-reduce per inner
    step, standard GSPMD). Used for the FSDP-sharded >10B archs where the
    divergent local copies of variant `local` cannot be represented (each
    data shard owns a param *slice*, not a replica). Still paper-faithful:
    it is exactly "inexact minibatch-prox with a distributed first-order
    solver"; the statistical large-batch benefit is retained while the
    communication saving is not — recorded honestly in EXPERIMENTS.md.

State kept per parameter: the anchor (1 vector) + inner momentum — versus
Adam's 2 moments; the held minibatch is token ids (cheap). This is the LM
analogue of the paper's "memory = b samples" column.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.optim.optimizers import Optimizer, sgd


@dataclasses.dataclass(frozen=True)
class MBProxConfig:
    gamma: float = 0.1            # prox strength (theory.py scaling)
    inner_lr: float = 0.02
    inner_momentum: float = 0.9
    inner_passes: int = 1         # epochs over the held minibatch
    dane_correction: bool = True  # gradient-correction all-reduce at anchor
    variant: str = "local"        # 'local' | 'sync'


def _tree_add(a, b, alpha=1.0):
    return jax.tree.map(lambda x, y: x + alpha * y.astype(x.dtype), a, b)


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y.astype(x.dtype), a, b)


def make_mbprox_step(loss_fn: Callable, mp_cfg: MBProxConfig, mesh,
                     dp_axes: tuple):
    """Returns mbprox_train_step(params, inner_state, batch, lr)
    -> (params, inner_state, metrics).

    loss_fn(params, microbatch) -> (loss, metrics); microbatch is a pytree
    whose leaves have a leading microbatch-batch dim.
    `batch` leaves: (n_micro, B_micro, ...).
    """
    inner_opt = sgd(momentum=mp_cfg.inner_momentum)

    def local_subproblem(params, inner_state, local_batch, lr):
        """Runs on ONE data shard (inside shard_map): local prox solve."""
        anchor = params

        if mp_cfg.dane_correction:
            def anchor_loss(p):
                # anchor gradient from the FIRST microbatch of the local
                # held minibatch (a stochastic DANE correction — one
                # microbatch, not an average over all n_micro)
                l, _ = loss_fn(p, jax.tree.map(lambda x: x[0], local_batch))
                return l
            g_loc = jax.grad(anchor_loss)(params)
            g_glob = jax.tree.map(
                lambda g: lax.pmean(g, dp_axes), g_loc)      # all-reduce #1
            correction = _tree_sub(g_glob, g_loc)
        else:
            correction = None

        def inner_step(carry, micro):
            p, s = carry
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, micro)
            # prox pull + DANE correction
            g = _tree_add(g, _tree_sub(p, anchor), mp_cfg.gamma)
            if correction is not None:
                g = _tree_add(g, correction)
            p, s = inner_opt.update(g, s, p, lr)
            return (p, s), l

        def one_pass(carry, _):
            return lax.scan(inner_step, carry, local_batch)

        (params, inner_state), losses = lax.scan(
            one_pass, (params, inner_state), None,
            length=mp_cfg.inner_passes)

        # average the local solutions (eq. 34)           # all-reduce #2
        params = jax.tree.map(lambda p: lax.pmean(p, dp_axes), params)
        inner_state = jax.tree.map(lambda s: lax.pmean(s, dp_axes),
                                   inner_state)
        return params, inner_state, lax.pmean(losses.mean(), dp_axes)

    def sync_subproblem(params, inner_state, batch, lr):
        """Synchronous inexact prox (plain GSPMD; per-step all-reduce)."""
        anchor = params

        def inner_step(carry, micro):
            p, s = carry
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, micro)
            g = _tree_add(g, _tree_sub(p, anchor), mp_cfg.gamma)
            p, s = inner_opt.update(g, s, p, lr)
            return (p, s), l

        def one_pass(carry, _):
            return lax.scan(inner_step, carry, batch)

        (params, inner_state), losses = lax.scan(
            one_pass, (params, inner_state), None,
            length=mp_cfg.inner_passes)
        return params, inner_state, losses.mean()

    if mp_cfg.variant == "sync":
        def step(params, inner_state, batch, lr):
            p, s, l = sync_subproblem(params, inner_state, batch, lr)
            return p, s, {"loss": l}
        return step

    # --- 'local' variant: shard_map manual over dp axes, auto over model ---
    def step(params, inner_state, batch, lr):
        batch_spec = jax.tree.map(lambda _: P(None, dp_axes), batch)

        fn = compat.shard_map(
            local_subproblem,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params),
                      jax.tree.map(lambda _: P(), inner_state),
                      batch_spec, P()),
            out_specs=(jax.tree.map(lambda _: P(), params),
                       jax.tree.map(lambda _: P(), inner_state), P()),
            check_vma=False,
            axis_names=set(dp_axes))
        p, s, l = fn(params, inner_state, batch, lr)
        return p, s, {"loss": l}

    return step
