"""Pure-pytree optimizers (no optax dependency — keeps sharding transparent:
every state leaf mirrors its param leaf so PartitionSpecs transfer 1:1)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable          # params -> opt_state
    update: Callable        # (grads, opt_state, params, lr) -> (new_p, new_s)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd(momentum: float = 0.0, nesterov: bool = False,
        state_dtype=None) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(
            lambda p: jnp.zeros_like(
                p, dtype=state_dtype or p.dtype), params)

    def _apply(p, s, lr):
        # update math in f32, cast back (bf16 params stay bf16)
        return (p.astype(jnp.float32)
                - lr * s.astype(jnp.float32)).astype(p.dtype)

    def update(grads, state, params, lr):
        if momentum == 0.0:
            new_p = jax.tree.map(lambda p, g: _apply(p, g, lr),
                                 params, grads)
            return new_p, ()
        new_m = jax.tree.map(
            lambda m, g: momentum * m + _cast_like(g, m), state, grads)
        if nesterov:
            step = jax.tree.map(
                lambda m, g: momentum * m + _cast_like(g, m), new_m, grads)
        else:
            step = new_m
        new_p = jax.tree.map(lambda p, s: _apply(p, s, lr), params, step)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=state_dtype or p.dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * _cast_like(g, m),
            state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * _cast_like(
                jnp.square(g.astype(jnp.float32)), v),
            state["v"], grads)

        def step(p, m, v):
            mh = m.astype(jnp.float32) / c1
            vh = v.astype(jnp.float32) / c2
            upd = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_p = jax.tree.map(step, params, new_m, new_v)
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Linear warmup + cosine decay."""
    peak: float
    warmup: int = 100
    total: int = 10000
    floor: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.peak * step / max(self.warmup, 1)
        frac = jnp.clip((step - self.warmup)
                        / max(self.total - self.warmup, 1), 0.0, 1.0)
        cos = self.floor + (1 - self.floor) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < self.warmup, warm, self.peak * cos)
