"""Gradient compression for the MBProx sync points.

The paper's communication unit is "vectors averaged across machines"; at
1000+ node scale the constant in front matters, so the two MBProx sync
points (anchor-gradient average, solution average) support:

  * int8 quantization with per-block scales + ERROR FEEDBACK (the residual
    is carried and added to the next round — keeps MBProx's inexactness
    theory applicable: compression error folds into eta_t of Thm 7),
  * top-k sparsification with error feedback.

Both operate leaf-wise on pytrees and compose with any reduction:
    compressed, state = compress(tree, state)
    averaged = pmean(decompress(compressed))
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class EFState(NamedTuple):
    residual: dict  # pytree like the grads


def init_ef(tree) -> EFState:
    return EFState(jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree))


# ----------------------------------------------------------------------------
# int8 with per-block scale
# ----------------------------------------------------------------------------

def _quant_leaf(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantize_int8(tree, ef: EFState):
    """Returns ((q_tree, scale_tree, shapes), new_ef)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    rflat = jax.tree.leaves(ef.residual)
    q_leaves, s_leaves, r_leaves = [], [], []
    for x, r in zip(flat, rflat):
        xe = x.astype(jnp.float32) + r
        q, s = _quant_leaf(xe)
        deq = _dequant_leaf(q, s, x.shape)
        q_leaves.append(q)
        s_leaves.append(s)
        r_leaves.append(xe - deq)
    unflatten = jax.tree_util.tree_unflatten
    q_tree = unflatten(treedef, q_leaves)
    s_tree = unflatten(treedef, s_leaves)
    new_ef = EFState(unflatten(treedef, r_leaves))
    shapes = jax.tree.map(lambda x: x.shape, tree)
    return (q_tree, s_tree, shapes), new_ef


def dequantize_int8(compressed):
    q_tree, s_tree, shapes = compressed
    return jax.tree.map(_dequant_leaf, q_tree, s_tree, shapes,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def compressed_bytes_int8(tree) -> int:
    """Wire bytes after int8 compression (payload + scales)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        blocks = -(-n // BLOCK)
        total += n + 4 * blocks
    return total


# ----------------------------------------------------------------------------
# top-k with error feedback
# ----------------------------------------------------------------------------

def topk_sparsify(tree, ef: EFState, frac: float = 0.01):
    """Keep the top `frac` entries by magnitude per leaf; returns
    ((values, indices, shapes), new_ef)."""
    def per_leaf(x, r):
        xe = x.astype(jnp.float32).reshape(-1) + r.reshape(-1)
        k = max(1, int(xe.size * frac))
        vals, idx = jax.lax.top_k(jnp.abs(xe), k)
        kept = xe[idx]
        dense = jnp.zeros_like(xe).at[idx].set(kept)
        return (kept, idx), (xe - dense).reshape(x.shape)

    flat, treedef = jax.tree_util.tree_flatten(tree)
    rflat = jax.tree.leaves(ef.residual)
    outs = [per_leaf(x, r) for x, r in zip(flat, rflat)]
    vals = jax.tree_util.tree_unflatten(treedef, [o[0][0] for o in outs])
    idxs = jax.tree_util.tree_unflatten(treedef, [o[0][1] for o in outs])
    new_ef = EFState(jax.tree_util.tree_unflatten(
        treedef, [o[1] for o in outs]))
    shapes = jax.tree.map(lambda x: x.shape, tree)
    return (vals, idxs, shapes), new_ef


def topk_densify(compressed):
    vals, idxs, shapes = compressed

    def per_leaf(v, i, shape):
        n = 1
        for d in shape:
            n *= d
        return jnp.zeros((n,), v.dtype).at[i].set(v).reshape(shape)

    return jax.tree.map(per_leaf, vals, idxs, shapes,
                        is_leaf=lambda x: isinstance(x, jax.Array))
