"""Version shims for JAX APIs that moved between releases.

The codebase is written against the current public API (``jax.set_mesh``,
``jax.shard_map``, ``pltpu.CompilerParams``); older installs expose the same
functionality under different names (``Mesh.__enter__`` as the ambient-mesh
context, ``jax.experimental.shard_map.shard_map`` with ``check_rep``/``auto``,
``pltpu.TPUCompilerParams``). Every call site routes through this module so
the rest of the tree never branches on the JAX version.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh, so bare
    PartitionSpecs in with_sharding_constraint / jit resolve against it."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    # jax<=0.4.x: Mesh is itself the ambient-mesh context manager
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """`jax.shard_map` with the current keyword API.

    On older JAX, translates to `jax.experimental.shard_map.shard_map`:
    `check_vma` -> `check_rep`, and `axis_names` (the manual axes) -> `auto`
    (its complement in the mesh).
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def pallas_tpu_compiler_params(**kwargs):
    """`pltpu.CompilerParams(...)` (renamed from TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
