"""Fault-tolerant pytree checkpointing: msgpack + zstd, atomic rename,
manifest with integrity hashes, restore-latest, async save thread.

Minibatch-prox makes checkpointing cheap (DESIGN.md §6): training state is
(params, anchor/opt, step, rng) ONLY — minibatches are redrawn from the
seeded stream, so no data-pipeline state needs recovery.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # optional dep: fall back to stdlib zlib
    zstandard = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    # sniff the frame magic so checkpoints stay readable across installs
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "'zstandard' package is not installed")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _encode(leaves) -> bytes:
    payload = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        # bf16 has no numpy dtype string; view as uint16
        if arr.dtype == jnp.bfloat16:
            payload.append({"dtype": "bfloat16", "shape": arr.shape,
                            "data": arr.view(np.uint16).tobytes()})
        else:
            payload.append({"dtype": str(arr.dtype), "shape": arr.shape,
                            "data": arr.tobytes()})
    raw = msgpack.packb(payload, use_bin_type=True)
    return _compress(raw)


def _decode(blob: bytes):
    raw = _decompress(blob)
    payload = msgpack.unpackb(raw, raw=False)
    leaves = []
    for item in payload:
        if item["dtype"] == "bfloat16":
            arr = np.frombuffer(item["data"], np.uint16).reshape(
                item["shape"]).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(item["data"],
                                np.dtype(item["dtype"])).reshape(
                item["shape"])
        leaves.append(jnp.asarray(arr))
    return leaves


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    blob = _encode(leaves)
    digest = hashlib.sha256(blob).hexdigest()
    name = f"ckpt_{step:010d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name + ".ckpt")
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)  # atomic on POSIX
    manifest = {"step": step, "sha256": digest, "time": time.time(),
                "treedef": str(treedef), "file": name + ".ckpt"}
    mtmp = os.path.join(ckpt_dir, "manifest.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.rename(mtmp, os.path.join(ckpt_dir, "manifest.json"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if f.endswith(".ckpt"))
    for f in ckpts[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f))
        except OSError:
            pass


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, tree_like):
    """Restore the latest checkpoint into the structure of `tree_like`.
    Verifies the manifest hash. Returns (tree, step) or (None, None)."""
    path = os.path.join(ckpt_dir, "manifest.json")
    if not os.path.exists(path):
        return None, None
    with open(path) as f:
        manifest = json.load(f)
    blob_path = os.path.join(ckpt_dir, manifest["file"])
    with open(blob_path, "rb") as f:
        blob = f.read()
    if hashlib.sha256(blob).hexdigest() != manifest["sha256"]:
        raise IOError(f"checkpoint {blob_path} failed integrity check")
    leaves = _decode(blob)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread = None

    def save(self, step: int, tree):
        self.wait()
        # device_get before handing to the thread (donations may invalidate)
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
