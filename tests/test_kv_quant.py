"""Quantized KV block pools + the host-RAM spill tier.

Covers the layers the quantized/tiered cache spans:
  * attention.quantize_kv/dequantize_kv — per-(slot, kv-head) scale
    round-trip error bound, exact zero handling, and the verbatim
    (q, scale) copy being a lossless round-trip;
  * kv_cache — pool dtype selection (fp16 keeps the activation dtype,
    fp8 gated on the jax build), dtype-aware paged_bytes/block_bytes,
    scale side-tables in init_paged_state, copy_block carrying scales,
    and gather_blocks/scatter_blocks round-tripping every pool leaf
    exactly (the host-tier payload path);
  * Pallas kernels — paged_attention and paged_prefill_attention with
    int8 pools + scale side-tables against their full-precision
    references (interpret mode);
  * BlockAllocator host tier — demote on eviction pressure, revive on
    the next prefix hit with payloads restored bit-exact and refcounts
    re-parked cached-free, the LRU capacity bound, and a hypothesis
    churn sweep asserting content-correct matches throughout;
  * engine — fp16 pools bit-identical to the default path, int8 greedy
    within the pinned per-token divergence budget, the tiered engine
    bit-identical to device-only while reviving spilled chains, the
    router probe counting spilled tokens without moving payloads, and
    host-tier promotion never compiling outside the bucket grid.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import lm
from repro.models.attention import (dequantize_kv, pool_qmax, quantize_kv,
                                    streamed_paged_attention)
from repro.serving import kv_cache
from repro.serving.block_manager import BlockAllocator
from repro.serving.bucketing import pick_bucket
from repro.serving.engine import (ServingEngine, shared_prefix_requests,
                                  summarize, synthetic_requests)
from repro.serving.replica import Replica

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def lists(*a, **k):
            return None

        @staticmethod
        def integers(*a, **k):
            return None

CFG = get_config("smollm-135m").reduced()


@functools.lru_cache(maxsize=1)
def _params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 8, 3, 16)) * 3.0
    q, scale = quantize_kv(x, jnp.int8)
    assert q.dtype == jnp.int8 and scale.shape == x.shape[:-1]
    y = dequantize_kv(q, scale)
    # symmetric rounding: error per element <= half a quantization step
    bound = np.asarray(scale)[..., None] * (0.5 + 1e-3) + 1e-6
    assert np.all(np.abs(np.asarray(y) - np.asarray(x)) <= bound)
    # an all-zero row quantizes (and dequantizes) to exact zeros
    z = jnp.zeros((2, 4, 1, 8))
    qz, sz = quantize_kv(z, jnp.int8)
    assert not np.any(np.asarray(qz)) and not np.any(np.asarray(sz))
    np.testing.assert_array_equal(np.asarray(dequantize_kv(qz, sz)), 0.0)
    assert pool_qmax(jnp.dtype(jnp.int8)) == 127.0


def test_pool_dtype_selection_and_fp8_gating():
    assert kv_cache.pool_dtype(CFG, "fp16") == CFG.act_dtype
    assert kv_cache.pool_dtype(CFG, "int8") == jnp.dtype(jnp.int8)
    assert not kv_cache.quantized("fp16") and kv_cache.quantized("int8")
    with pytest.raises(ValueError):
        kv_cache.pool_dtype(CFG, "int4")
    fp8 = getattr(jnp, "float8_e4m3fn", None)
    if fp8 is None:
        with pytest.raises(ValueError, match="fp8"):
            kv_cache.pool_dtype(CFG, "fp8")
    else:
        assert kv_cache.pool_dtype(CFG, "fp8") == jnp.dtype(fp8)


def test_paged_bytes_dtype_aware():
    nb, bs = 8, 16
    b16 = kv_cache.paged_bytes(CFG, nb, bs, "fp16")
    b8 = kv_cache.paged_bytes(CFG, nb, bs, "int8")
    assert 0 < b8 < b16               # int8 payload + f32 scale side-table
    # block_bytes is exactly the one-block slice of the pool, and the
    # pool total is linear in block count
    assert kv_cache.block_bytes(CFG, bs, "int8") == (
        kv_cache.paged_bytes(CFG, 1, bs, "int8"))
    assert b8 == nb * kv_cache.block_bytes(CFG, bs, "int8")


def test_init_state_scales_and_copy_block_carries_them():
    nb, bs = 6, 4
    state = kv_cache.init_paged_state(CFG, 1, nb, bs, kv_dtype="int8")
    layers = [st for st in state["prefix"] if isinstance(st, dict)
              and "k" in st]
    stacked = [v for v in state["blocks"].values()
               if isinstance(v, dict) and "k" in v]
    assert all("k_scale" in st and "v_scale" in st
               for st in layers + stacked)
    for v in stacked:
        assert v["k_scale"].shape == (CFG.n_super, nb, bs, CFG.n_kv_heads)
    # write recognizable payload + scale into block 2 of one stacked
    # pool, then COW-copy to block 4: both must carry over exactly
    name = next(iter(state["blocks"]))
    leaf = state["blocks"][name]
    k = leaf["k"].at[:, 2].set(7)
    ks = leaf["k_scale"].at[:, 2].set(0.5)
    state["blocks"][name] = dict(leaf, k=k, k_scale=ks)
    out = kv_cache.copy_block(CFG, state, jnp.int32(2), jnp.int32(4))
    got = out["blocks"][name]
    np.testing.assert_array_equal(np.asarray(got["k"][:, 4]),
                                  np.asarray(got["k"][:, 2]))
    np.testing.assert_array_equal(np.asarray(got["k_scale"][:, 4]),
                                  np.asarray(got["k_scale"][:, 2]))
    assert np.all(np.asarray(got["k_scale"][:, 4]) == 0.5)


def test_gather_scatter_blocks_exact_roundtrip():
    """The host-tier payload path: gather -> (host) -> scatter restores
    every pool leaf, including quantized payloads and scale tables."""
    nb, bs = 8, 4
    key = jax.random.PRNGKey(3)
    state = kv_cache.init_paged_state(CFG, 1, nb, bs, kv_dtype="int8")
    state = jax.tree.map(
        lambda x: (jax.random.randint(key, x.shape, -100, 100)
                   .astype(x.dtype) if x.dtype == jnp.int8 else
                   jax.random.uniform(key, x.shape, x.dtype)
                   if jnp.issubdtype(x.dtype, jnp.floating) else x),
        state)
    ids = jnp.asarray([2, 5, 1], jnp.int32)
    payload = kv_cache.gather_blocks(CFG, state, ids)
    blank = kv_cache.init_paged_state(CFG, 1, nb, bs, kv_dtype="int8")
    restored = kv_cache.scatter_blocks(CFG, blank, ids, payload)
    name = next(iter(state["blocks"]))
    for field in ("k", "v", "k_scale", "v_scale"):
        for b in (2, 5, 1):
            np.testing.assert_array_equal(
                np.asarray(restored["blocks"][name][field][:, b]),
                np.asarray(state["blocks"][name][field][:, b]))


# ---------------------------------------------------------------------------
# kernels: quantized pools vs full-precision references
# ---------------------------------------------------------------------------

def test_paged_attention_kernel_quantized_matches_ref():
    rng = np.random.default_rng(0)
    B, H, KV, hd, bs, nb, M = 2, 4, 2, 16, 8, 12, 3
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (B, H, hd))
    kp = jax.random.normal(jax.random.fold_in(key, 1), (nb, bs, KV, hd))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (nb, bs, KV, hd))
    bt = jnp.asarray(rng.choice(nb - 1, size=(B, M), replace=False) + 1,
                     jnp.int32)
    cl = jnp.asarray([bs * M, bs * 2 - 3], jnp.int32)
    qk, sk = quantize_kv(kp, jnp.int8)
    qv, sv = quantize_kv(vp, jnp.int8)
    ref = paged_attention_ref(q, qk, qv, bt, cl, k_scale=sk, v_scale=sv)
    got = paged_attention(q, qk, qv, bt, cl, k_scale=sk, v_scale=sv,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # the ref with scales equals dense dequant-then-attend exactly
    dense = paged_attention_ref(q, dequantize_kv(qk, sk),
                                dequantize_kv(qv, sv), bt, cl)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               atol=1e-6, rtol=1e-6)


def test_paged_prefill_kernel_quantized_matches_oracle():
    KEY = jax.random.PRNGKey(0)

    def _rand(i, shape):
        return jax.random.normal(jax.random.fold_in(KEY, i),
                                 shape).astype(jnp.float32)

    N, Ls, H, KV, hd, bs, M, P = 3, 16, 4, 2, 16, 4, 8, 20
    starts, lengths = (0, 7, 20), (10, 23, 0)
    q = _rand(0, (N, Ls, H, hd))
    k_suf, v_suf = _rand(1, (N, Ls, KV, hd)), _rand(2, (N, Ls, KV, hd))
    k_pool, v_pool = _rand(3, (P, bs, KV, hd)), _rand(4, (P, bs, KV, hd))
    rng = np.random.default_rng(0)
    bt = rng.integers(1, P, (N, M)).astype(np.int32)
    st_ = np.minimum(np.asarray(starts, np.int32), M * bs)
    ln = np.asarray(lengths, np.int32)
    pos = st_[:, None] + np.arange(Ls)[None, :].astype(np.int32)
    qk, sk = quantize_kv(k_pool, jnp.int8)
    qv, sv = quantize_kv(v_pool, jnp.int8)
    cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    oracle = streamed_paged_attention(
        q, k_suf, v_suf, cache, jnp.asarray(bt), jnp.asarray(pos),
        jnp.asarray(st_), jnp.asarray(ln), scale=hd**-0.5,
        attn_chunk=8, window=0)
    got = paged_prefill_attention(
        q, k_suf, v_suf, qk, qv, jnp.asarray(bt), jnp.asarray(st_),
        jnp.asarray(ln), k_scale=sk, v_scale=sv, window=0, bq=8,
        interpret=True)
    for n in range(N):
        s = int(np.clip(ln[n] - st_[n], 0, Ls))
        if s:
            np.testing.assert_allclose(np.asarray(got)[n, :s],
                                       np.asarray(oracle)[n, :s],
                                       atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# allocator host tier
# ---------------------------------------------------------------------------

def _host_alloc(num_blocks, bs, cap, payloads):
    def fetch(b):
        return payloads[b].copy()

    def store(ids, pls):
        for b, p in zip(ids, pls):
            payloads[b] = np.array(p)

    return BlockAllocator(num_blocks, block_size=bs,
                          host_cache_blocks=cap, fetch_block=fetch,
                          store_blocks=store)


def test_host_tier_demote_revive_roundtrip():
    bs = 2
    payloads = {}
    alloc = _host_alloc(6, bs, 8, payloads)
    prompt = np.array([1, 2, 3, 4], np.int32)
    blocks = alloc.alloc(2)
    for j, b in enumerate(blocks):
        payloads[b] = prompt[j * bs:(j + 1) * bs] * 10  # "device KV"
    originals = {j: payloads[b].copy() for j, b in enumerate(blocks)}
    alloc.register_prefix(prompt, blocks)
    alloc.free(blocks)                      # -> cached-free
    # pressure: taking every block demotes the chain to the host tier
    taken = alloc.alloc(5)
    assert taken is not None
    assert alloc.host_demotions == 2 and alloc.num_spilled == 2
    assert alloc.match_prefix(prompt, promote=False).spilled_tokens == 4
    for b in taken:                          # scribble over the device KV
        payloads[b] = np.full(bs, -1, np.int32)
    alloc.free(taken)
    # the next prefix hit revives the chain: payloads restored bit-exact,
    # blocks re-registered cached-free under their original keys
    m = alloc.match_prefix(prompt)
    assert m.tokens(bs) == 4 and alloc.host_revivals == 2
    assert alloc.num_spilled == 0
    for j, b in enumerate(m.full_blocks):
        np.testing.assert_array_equal(payloads[b], originals[j])
        assert alloc.refcount(b) == 0        # parked cached-free
    assert alloc.num_cached == 2
    alloc.share(m)                           # admission takes references
    assert all(alloc.refcount(b) == 1 for b in m.full_blocks)
    alloc.unshare(m)
    # conservation with the tier in play (num_free counts cached-free
    # blocks — they are allocatable on demand)
    assert alloc.num_free == 5 and alloc.num_cached == 2


def test_host_tier_lru_capacity_and_reset():
    bs = 2
    payloads = {}
    alloc = _host_alloc(6, bs, 1, payloads)  # capacity: one spilled block
    prompt = np.array([1, 2, 3, 4], np.int32)
    blocks = alloc.alloc(2)
    for j, b in enumerate(blocks):
        payloads[b] = prompt[j * bs:(j + 1) * bs]
    alloc.register_prefix(prompt, blocks)
    alloc.free(blocks)
    taken = alloc.alloc(5)
    assert alloc.host_demotions == 2 and alloc.num_spilled == 1  # LRU bound
    alloc.free(taken)
    alloc.reset_prefix_cache()
    assert alloc.num_spilled == 0            # reset clears the tier too


def test_host_tier_noop_without_callbacks():
    alloc = BlockAllocator(6, block_size=2, host_cache_blocks=8)
    prompt = np.array([1, 2, 3, 4], np.int32)
    blocks = alloc.alloc(2)
    alloc.register_prefix(prompt, blocks)
    alloc.free(blocks)
    taken = alloc.alloc(5)
    assert taken is not None and alloc.num_spilled == 0
    assert alloc.host_demotions == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=60))
def test_host_tier_churn_content_correct(seeds):
    """Random admit/free churn over prompts with shared prefixes and a
    pool small enough to keep demoting: every match (device-resident or
    revived from the host tier) must return blocks whose payload equals
    the prompt's corresponding chunk, and block conservation holds."""
    bs = 2
    n_blocks = 8
    prompts = [np.array(p, np.int32) for p in (
        [1, 2, 3, 4, 5, 6], [1, 2, 3, 4, 9, 9], [5, 5, 5, 5],
        [7, 8], [1, 2, 3, 4, 5, 6, 7, 8])]
    payloads = {}
    alloc = _host_alloc(n_blocks, bs, 6, payloads)
    live = []
    for s in seeds:
        if s % 2 == 0:                       # admit
            prompt = prompts[s // 2 % len(prompts)]
            nfull = len(prompt) // bs
            m = alloc.match_prefix(prompt)   # may revive from the tier
            for j, b in enumerate(m.full_blocks):
                np.testing.assert_array_equal(
                    payloads[b], prompt[j * bs:(j + 1) * bs])
            alloc.share(m)
            fresh = alloc.alloc(nfull - len(m.full_blocks))
            if fresh is None:
                alloc.unshare(m)
                continue
            if m.partial_block is not None:  # not needed: all-full chain
                alloc.decref(m.partial_block)
            blocks = list(m.full_blocks) + list(fresh)
            for j, b in enumerate(blocks):
                if alloc.is_writable(b):
                    payloads[b] = np.array(prompt[j * bs:(j + 1) * bs])
            alloc.register_prefix(prompt[:nfull * bs], blocks)
            live.append(blocks)
        elif live:                           # finish a sequence
            alloc.free(live.pop(s % len(live)))
        held = set(b for h in live for b in h)
        # conservation: num_free (incl. cached-free) + referenced
        assert alloc.num_free + len(held) == n_blocks - 1
    for h in live:
        alloc.free(h)


# ---------------------------------------------------------------------------
# engine: identity gates, divergence budget, tier revival, bucket bound
# ---------------------------------------------------------------------------

def _run_engine(reqs, max_seq, slots=4, **kw):
    eng = ServingEngine(_params(), CFG, num_slots=slots, block_size=16,
                        max_seq_len=max_seq, **kw)
    done = eng.run(list(reqs))
    eng.last_stats = summarize(done, max(eng.wall_time, 1e-9), eng)
    return {c.rid: list(map(int, c.tokens)) for c in done}, eng


def _pinned_reqs():
    return synthetic_requests(8, vocab_size=CFG.vocab_size,
                              prompt_len=(16, 48), max_new=(8, 16), seed=0)


def test_engine_fp16_bit_identity_and_int8_budget():
    base, _ = _run_engine(_pinned_reqs(), 80)
    fp16, _ = _run_engine(_pinned_reqs(), 80, kv_dtype="fp16")
    assert base == fp16, "fp16 pools changed greedy output"
    i8, eng = _run_engine(_pinned_reqs(), 80, kv_dtype="int8")
    tot = sum(len(v) for v in base.values())
    mism = sum(x != y for r in base for x, y in zip(base[r], i8[r]))
    # the pinned per-token divergence budget (measured 0 on this fixed
    # workload; 10% margin catches a broken quantizer, not jitter)
    assert mism / tot <= 0.10, f"int8 divergence {mism}/{tot}"
    assert eng.kv_dtype == "int8"
    assert eng.cache_bytes == kv_cache.paged_bytes(
        CFG, eng.allocator.num_blocks, eng.block_size, "int8")


def test_engine_rejects_unknown_kv_dtype():
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(_params(), CFG, num_slots=2, block_size=16,
                      max_seq_len=64, kv_dtype="int4")


def _tiered_reqs():
    # 4 rotating system prompts vs a slots-only pool: every admission
    # evicts the other prefix chains, so revival is the only way a
    # later request of the same tenant finds its prefix cached
    return shared_prefix_requests(16, vocab_size=CFG.vocab_size,
                                  prefix_len=48, suffix_len=(8, 16),
                                  max_new=(4, 8), n_prefixes=4, seed=0)


def test_tiered_engine_identity_revival_and_gain():
    kw = dict(slots=2, prefix_cache=True, num_blocks=13)
    dev, dev_eng = _run_engine(_tiered_reqs(), 96, **kw)
    tier, eng = _run_engine(_tiered_reqs(), 96, host_cache_blocks=32, **kw)
    assert dev == tier, "host spill tier changed greedy output"
    assert eng.allocator.host_revivals >= 1
    assert eng.allocator.host_demotions >= eng.allocator.host_revivals
    s_dev = dev_eng.last_stats
    s_tier = eng.last_stats
    assert (s_tier["prefill"]["cached_tokens"]
            > s_dev["prefill"]["cached_tokens"])
    kv = s_tier["kv"]
    assert kv["dtype"] == "fp16" and kv["host_cache_blocks"] == 32
    assert kv["host_pool_bytes"] == 32 * eng.runner.block_bytes
    assert kv["host_revivals"] == eng.allocator.host_revivals
    # scheduler stats surface the spilled tier for the router
    assert eng.stats().spilled_blocks == eng.allocator.num_spilled


def test_tiered_int8_roundtrip_identity():
    kw = dict(slots=2, prefix_cache=True, num_blocks=13, kv_dtype="int8")
    a, _ = _run_engine(_tiered_reqs(), 96, **kw)
    b, eng = _run_engine(_tiered_reqs(), 96, host_cache_blocks=32, **kw)
    assert a == b, "int8 demote/revive is not an exact round-trip"
    assert eng.allocator.host_revivals >= 1


def test_promotion_stays_on_bucket_grid():
    kw = dict(slots=2, prefix_cache=True, num_blocks=13,
              host_cache_blocks=32)
    _, eng = _run_engine(_tiered_reqs(), 96, **kw)
    shapes = eng.runner.promote_shapes
    buckets = eng.runner.promote_buckets
    assert shapes, "tiered run never promoted"
    assert shapes <= set(buckets)
    assert all(w == pick_bucket(w, buckets) for w in shapes)


def test_replica_probe_counts_spilled_tokens_readonly():
    rep = Replica(_params(), CFG, num_slots=2, block_size=16,
                  max_seq_len=96, prefix_cache=True, num_blocks=13,
                  host_cache_blocks=32)
    rep.engine.run(_tiered_reqs())
    rev0 = rep.engine.allocator.host_revivals
    probe = rep.probe_prefix(_tiered_reqs()[0].prompt)
    assert probe >= 48                       # sees the spilled prefix
    assert rep.engine.allocator.host_revivals == rev0  # probe is read-only
    assert rep.snapshot().spilled_blocks == rep.engine.allocator.num_spilled
