"""Autoscaler policy units: the AutoscaleController state machine over
synthetic stat series (scale-out latency bound, hysteresis/no-flapping,
cooldown spacing, min/max clamps) and the Autoscaler lifecycle over a
real Router with stub replicas (standby activation, drain + reclaim,
per-run reset, elastic membership guards). No device, no engine — the
end-to-end autoscaled bit-identity gates live in serving_bench's bursty
arm and the CI autoscale smoke."""
import types

import numpy as np
import pytest

from repro.serving.autoscaler import (Autoscaler, AutoscaleController,
                                      AutoscalePolicy)
from repro.serving.replica import ReplicaSnapshot
from repro.serving.router import Router
from repro.serving.scheduler import SchedulerStats

pytestmark = pytest.mark.serving


# ----------------------------------------------------------------------------
# controller units over synthetic series
# ----------------------------------------------------------------------------

def _policy(**kw):
    base = dict(min_replicas=1, max_replicas=4, queue_high=2.0,
                queue_low=1.0, high_window_s=0.1, low_window_s=0.2,
                cooldown_s=0.0)
    base.update(kw)
    return AutoscalePolicy(**base)


def test_policy_validation():
    with pytest.raises(ValueError):
        AutoscalePolicy(min_replicas=0)
    with pytest.raises(ValueError):
        AutoscalePolicy(min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError):
        AutoscalePolicy(queue_high=1.0, queue_low=1.0)


def test_scale_out_latency_bound():
    """Sustained pressure must convert to a scale-out within one sample
    period past the high window — the reaction-time guarantee."""
    ctl = AutoscaleController(_policy())
    fired = None
    for i in range(20):
        t = i * 0.02
        if ctl.observe(t, queue_depth=10, active_slots=2,
                       n_replicas=1) == "out":
            fired = t
            break
    assert fired is not None
    assert 0.1 <= fired <= 0.12 + 1e-9


def test_no_decision_on_oscillating_series():
    """A queue that blips above the threshold but never SUSTAINS it must
    never scale — the window resets on every dip (anti-flapping)."""
    ctl = AutoscaleController(_policy(high_window_s=0.1, low_window_s=9.0))
    for i in range(200):
        qd = 10 if i % 2 == 0 else 0
        # 0.05s samples: each high stretch lasts < high_window_s
        assert ctl.observe(i * 0.05, qd, 2, 1) is None


def test_decision_consumes_window_and_cooldown_spaces_decisions():
    """Back-to-back scale-outs under constant pressure are spaced by at
    least cooldown AND a fresh sustain window each."""
    ctl = AutoscaleController(_policy(cooldown_s=0.25))
    fired = []
    for i in range(100):
        t = i * 0.02
        if ctl.observe(t, 10, 2, 1) == "out":
            fired.append(t)
    assert len(fired) >= 2
    gaps = np.diff(fired)
    assert (gaps >= 0.25 - 1e-9).all()
    assert (gaps >= 0.1 - 1e-9).all()       # window re-accumulates too


def test_min_max_clamps():
    ctl = AutoscaleController(_policy(max_replicas=2))
    # at the ceiling: sustained pressure never scales out
    for i in range(30):
        assert ctl.observe(i * 0.02, 10, 2, 2) is None
    ctl = AutoscaleController(_policy())
    # at the floor: sustained idleness never scales in
    for i in range(30):
        assert ctl.observe(i * 0.02, 0, 0, 1) is None


def test_scale_in_after_sustained_idle_and_hysteresis_band():
    ctl = AutoscaleController(_policy())
    fired = None
    for i in range(30):
        t = i * 0.02
        if ctl.observe(t, 0, 0, 2) == "in":
            fired = t
            break
    assert fired is not None and fired >= 0.2 - 1e-9
    # load in the hysteresis band (between low and high): no decision
    ctl = AutoscaleController(_policy())
    for i in range(50):
        # per-replica queue 1.5: above queue_low, below queue_high
        assert ctl.observe(i * 0.02, 3, 0, 2) is None


def test_reset_clears_accumulated_windows():
    ctl = AutoscaleController(_policy())
    ctl.observe(0.0, 10, 2, 1)
    ctl.reset()
    # window restarts: nothing fires until a full fresh window elapses
    assert ctl.observe(0.09, 10, 2, 1) is None
    assert ctl.observe(0.19, 10, 2, 1) == "out"


# ----------------------------------------------------------------------------
# Autoscaler lifecycle over a real Router with stub replicas
# ----------------------------------------------------------------------------

class _StubReplica:
    """Duck-typed replica with settable occupancy + lifecycle spies."""

    def __init__(self, rid, *, slots=2, queue=0, active=0, enabled=True):
        self.replica_id = rid
        self.enabled = enabled
        self.num_slots = slots
        self.queue_depth = queue
        self.active = active
        self.submitted = []
        self.begin_runs = 0
        self.cache_resets = 0
        self.engine = types.SimpleNamespace(
            block_size=4,
            runner=types.SimpleNamespace(prefill_max_batch=slots))
        self.scheduler = types.SimpleNamespace(on_event=None,
                                               preemptions=0, resumes=0)

    def snapshot(self):
        return ReplicaSnapshot(
            replica_id=self.replica_id, enabled=self.enabled,
            stats=SchedulerStats(
                queue_depth=self.queue_depth, active_slots=self.active,
                free_slots=self.num_slots - self.active, free_blocks=99,
                cached_blocks=0, indexed_blocks=0, reserved_blocks=0))

    def probe_prefix(self, prompt):
        return 0

    def submit(self, req):
        self.submitted.append(req)
        self.queue_depth += 1

    @property
    def has_work(self):
        return bool(self.submitted) or self.active > 0

    def take_queued(self):
        out, self.submitted, self.queue_depth = self.submitted, [], 0
        return out

    def take_completions(self):
        return []

    def begin_run(self, t0=None):
        self.begin_runs += 1

    def align_clock(self, t0):
        pass

    def reset_prefix_cache(self):
        self.cache_resets += 1


def _autoscaled_pair():
    base = _StubReplica(0)
    standby = _StubReplica(1)
    router = Router([base], policy="least-loaded")
    asc = Autoscaler(router, policy=_policy(max_replicas=2,
                                            cooldown_s=0.0),
                     standby=[standby])
    return base, standby, router, asc


def test_autoscaler_attaches_and_rejects_duplicate_ids():
    base, standby, router, asc = _autoscaled_pair()
    assert router.autoscaler is asc
    with pytest.raises(ValueError):
        Autoscaler(Router([_StubReplica(0)]), standby=[_StubReplica(0)])


def test_scale_out_activates_standby_then_drain_and_reclaim():
    base, standby, router, asc = _autoscaled_pair()
    base.queue_depth, base.active = 6, 2
    assert asc.tick(0.0) is None                  # window accumulating
    assert asc.tick(0.11) == "out"
    assert standby in router.replicas and standby.enabled
    assert asc.scale_out_events == 1 and not asc._standby
    # burst passes: both replicas idle -> drain the ADDED one
    base.queue_depth = base.active = 0
    assert asc.tick(0.2) is None                  # low window accumulating
    assert asc.tick(0.45) == "in"
    assert not standby.enabled and asc.scale_in_events == 1
    assert standby in router.replicas             # still draining
    # drained stub has no work -> reclaimed to standby, cache dropped
    resets = standby.cache_resets
    assert asc.tick(0.5) is None
    assert standby not in router.replicas
    assert asc._standby == [standby] and asc.reclaims == 1
    assert standby.cache_resets == resets + 1
    assert [e["event"] for e in asc.events] == ["scale-out", "scale-in",
                                                "reclaim"]


def test_draining_replica_with_work_is_not_reclaimed():
    base, standby, router, asc = _autoscaled_pair()
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    asc.tick(0.11)                                # scale-out
    standby.active = 1                            # running a lane
    base.queue_depth = base.active = 0
    asc.tick(0.2)
    asc.tick(0.45)                                # scale-in -> draining
    asc.tick(0.5)
    assert standby in router.replicas and asc.reclaims == 0
    standby.active = 0                            # lane finished
    asc.tick(0.55)
    assert standby not in router.replicas and asc.reclaims == 1


def test_scale_out_cancels_drain_before_touching_standby():
    base, standby, router, asc = _autoscaled_pair()
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    asc.tick(0.11)                                # out: standby joins
    standby.active = 1                            # keeps it draining
    base.queue_depth = base.active = 0
    asc.tick(0.2)
    asc.tick(0.45)                                # in: standby drains
    assert not standby.enabled
    base.queue_depth, base.active = 6, 2          # pressure returns
    standby.queue_depth = 0
    asc.tick(0.5)
    assert asc.tick(0.61) == "out"
    assert standby.enabled                        # drain cancelled,
    assert asc._standby == []                     # no pool churn


def test_skipped_scale_out_when_no_capacity_source():
    base = _StubReplica(0)
    router = Router([base])
    asc = Autoscaler(router, policy=_policy(max_replicas=2,
                                            cooldown_s=0.0))
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    assert asc.tick(0.11) is None
    assert asc.skipped_scale_outs == 1 and asc.scale_out_events == 0


def test_spawn_factory_used_when_standby_empty():
    base = _StubReplica(0)
    router = Router([base])
    spawned = []

    def spawn(rid):
        rep = _StubReplica(rid)
        spawned.append(rep)
        return rep

    asc = Autoscaler(router, policy=_policy(max_replicas=2,
                                            cooldown_s=0.0), spawn=spawn)
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    assert asc.tick(0.11) == "out"
    assert spawned and spawned[0].replica_id == 1   # fresh unique id
    assert spawned[0] in router.replicas


def test_begin_run_retires_added_replicas_and_reenables_base():
    base, standby, router, asc = _autoscaled_pair()
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    asc.tick(0.11)                                # standby joined
    base.enabled = False                          # e.g. drained last run
    asc.begin_run(0.0)
    assert router.replicas == [base] and base.enabled
    assert asc._standby == [standby]
    assert standby.begin_runs >= 1                # clean telemetry
    assert asc.scale_out_events == 0 and asc.events == []
    assert asc.summary()["standby_replicas"] == 1


def test_router_membership_guards():
    base, standby, router, asc = _autoscaled_pair()
    with pytest.raises(RuntimeError):
        router.remove_replica(0)                  # never the last one
    base.queue_depth, base.active = 6, 2
    asc.tick(0.0)
    asc.tick(0.11)
    with pytest.raises(ValueError):
        router.add_replica(_StubReplica(1))       # duplicate id
    base.active = 1
    base.queue_depth = 0
    with pytest.raises(RuntimeError):
        router.remove_replica(0)                  # still has work
    with pytest.raises(KeyError):
        router.remove_replica(7)


def test_bursty_workload_reproducible_and_actually_bursty():
    from repro.serving.engine import bursty_requests
    kw = dict(vocab_size=100, base_rate=1.0, burst_rate=200.0,
              burst_every=100.0, burst_len=0.1, priorities=(0, 1, 2))
    a = bursty_requests(40, seed=5, **kw)
    b = bursty_requests(40, seed=5, **kw)
    assert all(np.array_equal(x.prompt, y.prompt) and
               x.arrival == y.arrival and x.priority == y.priority
               for x, y in zip(a, b))                 # seeded
    arr = np.array([r.arrival for r in a])
    assert (np.diff(arr) > 0).all()                    # strictly ordered
    # the burst is real: ~burst_rate*burst_len arrivals land inside the
    # window, the rest trickle at base_rate (so they span seconds)
    assert (arr <= 0.1).sum() >= 12
    assert arr[-1] > 5.0
    assert {r.priority for r in a} <= {0, 1, 2}
    c = bursty_requests(40, seed=6, **kw)
    assert any(x.arrival != y.arrival for x, y in zip(a, c))


def test_bursty_workload_weights_and_validation():
    from repro.serving.engine import bursty_requests
    reqs = bursty_requests(16, vocab_size=50, priorities=(0, 5),
                           priority_weights=(0.0, 1.0), seed=0)
    assert all(r.priority == 5 for r in reqs)
    with pytest.raises(ValueError):
        bursty_requests(4, vocab_size=50, priorities=(0, 1),
                        priority_weights=(1.0,))
    with pytest.raises(ValueError):
        bursty_requests(4, vocab_size=50, base_rate=0.0)


def test_multi_tenant_priority_mix_keeps_rng_stream():
    """tenant_priorities stamps classes per tenant WITHOUT consuming
    extra rng draws — committed bench records depend on the stream."""
    from repro.serving.engine import multi_tenant_requests
    base = multi_tenant_requests(12, vocab_size=50, n_tenants=3, seed=3)
    pri = multi_tenant_requests(12, vocab_size=50, n_tenants=3, seed=3,
                                tenant_priorities=[2, 0, 1])
    assert all(np.array_equal(x.prompt, y.prompt) and
               x.arrival == y.arrival and
               x.max_new_tokens == y.max_new_tokens
               for x, y in zip(base, pri))
    assert {r.priority for r in base} == {0}
    assert {r.priority for r in pri} <= {0, 1, 2}
    assert any(r.priority > 0 for r in pri)
    # weights skew traffic: all mass on tenant 0 -> one shared prefix
    skew = multi_tenant_requests(12, vocab_size=50, n_tenants=3, seed=3,
                                 tenant_weights=[1.0, 0.0, 0.0],
                                 tenant_priorities=[4, 0, 0])
    assert all(r.priority == 4 for r in skew)
    with pytest.raises(ValueError):
        multi_tenant_requests(4, vocab_size=50, n_tenants=3,
                              tenant_priorities=[1])
    with pytest.raises(ValueError):
        multi_tenant_requests(4, vocab_size=50, n_tenants=3,
                              tenant_weights=[0.5, 0.5])


def test_summary_shape():
    _, _, _, asc = _autoscaled_pair()
    s = asc.summary()
    assert s["policy"]["max_replicas"] == 2
    assert s["enabled_replicas"] == 1 and s["standby_replicas"] == 1
    for key in ("scale_out_events", "scale_in_events", "reclaims",
                "skipped_scale_outs", "events"):
        assert key in s
