"""SLO layer: quantile-sketch accuracy against the exact order
statistic (the pinned relative-error bound, on adversarial
distributions and — when installed — under hypothesis), burn-rate
window semantics, SLOSignal scaling decisions, deadline shed/defer
admission on a real engine (with the bit-identity gate: SLO tracking
plus an armed-but-untriggered shedder must never change tokens), the
flight recorder's bounded ring + anomaly triggers, the diurnal
workload generator, and the v2 metrics-dump schema (v1 back-compat
included)."""
import json
import math

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import (ServingEngine, diurnal_requests,
                                  summarize, synthetic_requests)
from repro.serving.observability import (METRICS_SCHEMA, METRICS_SCHEMAS,
                                         FlightRecorder, Observability,
                                         metrics_dump,
                                         validate_metrics_dump,
                                         validate_trace_events)
from repro.serving.sampling import SamplingParams
from repro.serving.slo import (QuantileSketch, SLOPolicy, SLOSignal,
                               SLOTracker)

pytestmark = pytest.mark.serving


# ----------------------------------------------------------------------------
# quantile sketch: the relative-error bound is the whole contract
# ----------------------------------------------------------------------------

def _exact_nearest_rank(vals, q):
    s = sorted(vals)
    return s[min(max(1, math.ceil(q * len(s))) - 1, len(s) - 1)]


def _assert_within_bound(vals, rel_err=0.01):
    sk = QuantileSketch(rel_err)
    for v in vals:
        sk.observe(float(v))
    for q in (0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0):
        exact = _exact_nearest_rank(vals, q)
        est = sk.quantile(q)
        assert abs(est - exact) <= rel_err * exact + 1e-12, \
            (q, est, exact)


def test_sketch_bound_on_adversarial_distributions():
    rng = np.random.default_rng(0)
    _assert_within_bound(rng.lognormal(0.0, 2.0, 4000))     # heavy tail
    _assert_within_bound(rng.pareto(1.1, 4000) + 1e-3)      # heavier
    _assert_within_bound(np.full(100, 0.123))               # constant
    _assert_within_bound(np.concatenate([                   # bimodal,
        rng.normal(0.001, 1e-5, 2000).clip(1e-4),           # 5 decades
        rng.normal(100.0, 1.0, 2000).clip(1.0)]))           # apart
    _assert_within_bound(np.geomspace(1e-4, 3.5e3, 999))    # every decade
    _assert_within_bound([5.0])                             # single value
    _assert_within_bound(np.arange(1, 100, dtype=float), rel_err=0.05)


def test_sketch_bound_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.floats(min_value=1e-4, max_value=3.5e3),
                        min_size=1, max_size=300),
               st.floats(min_value=0.0, max_value=1.0))
    @hyp.settings(max_examples=200, deadline=None)
    def prop(vals, q):
        sk = QuantileSketch(0.01)
        for v in vals:
            sk.observe(v)
        exact = _exact_nearest_rank(vals, q)
        assert abs(sk.quantile(q) - exact) <= 0.01 * exact + 1e-12

    prop()


def test_sketch_clamps_and_memory_is_fixed():
    sk = QuantileSketch(0.01, min_value=1e-3, max_value=10.0)
    n_buckets = len(sk.counts)
    for v in (-1.0, 0.0, 1e-9, 5.0, 100.0, 1e9):
        sk.observe(v)
    assert len(sk.counts) == n_buckets       # never grows
    assert sk.quantile(0.0) == sk.min_value  # floor clamp
    assert sk.quantile(1.0) <= 10.0 * (1 + 0.01) * 2  # ceiling clamp
    with pytest.raises(ValueError):
        sk.quantile(1.5)
    with pytest.raises(ValueError):
        QuantileSketch(0.0)
    with pytest.raises(ValueError):
        QuantileSketch(0.01, min_value=2.0, max_value=1.0)


def test_sketch_merge_equals_concatenated_stream():
    rng = np.random.default_rng(1)
    a_vals = rng.lognormal(0, 1, 500)
    b_vals = rng.lognormal(2, 1, 700)
    a, b, both = (QuantileSketch(0.01) for _ in range(3))
    for v in a_vals:
        a.observe(v)
        both.observe(v)
    for v in b_vals:
        b.observe(v)
        both.observe(v)
    a.merge(b)
    assert a.counts == both.counts and a.count == both.count
    assert a.quantile(0.9) == both.quantile(0.9)
    with pytest.raises(ValueError):
        a.merge(QuantileSketch(0.02))


def test_sketch_empty_reset_and_dump():
    sk = QuantileSketch(0.01)
    assert sk.quantile(0.5) is None and sk.mean == 0.0
    sk.observe(1.0)
    sk.observe(3.0)
    assert sk.mean == 2.0
    d = sk.to_dict()
    assert d["count"] == 2 and d["sum"] == 4.0
    assert sum(c for _, c in d["buckets"]) == 2
    sk.reset()
    assert sk.count == 0 and sk.quantile(0.5) is None


# ----------------------------------------------------------------------------
# policy + burn-rate windows
# ----------------------------------------------------------------------------

def test_policy_validation_and_class_objectives():
    p = SLOPolicy(ttft_objective_ms=100.0, class_ttft_ms=((2, 50.0),))
    assert p.ttft_objective_s(0) == 0.1
    assert p.ttft_objective_s(2) == 0.05
    assert p.latency_objective_s() is None
    for bad in (dict(ttft_objective_ms=0),
                dict(class_ttft_ms=((1, -5.0),)),
                dict(latency_objective_ms=0.0),
                dict(error_budget=0.0), dict(error_budget=1.0),
                dict(fast_window_s=2.0, slow_window_s=1.0)):
        with pytest.raises(ValueError):
            SLOPolicy(**bad)


def test_burn_rate_math_and_idle_semantics():
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=200.0, error_budget=0.1,
                              fast_window_s=0.25, slow_window_s=1.0))
    # cold: no observation ever -> no burn defined (None, not 0)
    assert tr.burn_rate(0.0, 1.0) is None
    assert tr.tick(0.0) == (None, None)
    # half the observations breach -> fraction 0.5 -> burn 5.0 at
    # budget 0.1
    for i in range(20):
        t = i * 0.05
        breached = tr.observe_ttft(t, 0.5 if i % 2 else 0.01)
        assert breached == bool(i % 2)
    assert tr.burn_rate(0.95, 1.0) == pytest.approx(5.0)
    assert tr.breaches["ttft"] == 10
    fast, slow = tr.tick(0.95)
    assert slow == pytest.approx(5.0)
    assert tr.peak_burn["slow"] == pytest.approx(5.0)
    # idle after traffic: the window drains to burn 0.0, never None
    assert tr.burn_rate(60.0, 1.0) == 0.0
    tr.reset()
    assert tr.burn_rate(61.0, 1.0) is None   # reset forgets `ever` too
    assert tr.breaches["ttft"] == 0 and tr.peak_burn["fast"] == 0.0


def test_tracker_quantiles_per_class_and_merged():
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=100.0))
    for _ in range(50):
        tr.observe_ttft(0.0, 0.01, priority=0)    # fast class
        tr.observe_ttft(0.0, 1.0, priority=1)     # slow class
    assert tr.ttft_quantile(0.5, priority=0) == pytest.approx(0.01,
                                                              rel=0.02)
    assert tr.ttft_quantile(0.5, priority=1) == pytest.approx(1.0,
                                                              rel=0.02)
    # merged across classes: the median straddles both populations
    assert tr.ttft_quantile(0.25) == pytest.approx(0.01, rel=0.02)
    assert tr.ttft_quantile(0.75) == pytest.approx(1.0, rel=0.02)
    assert tr.ttft_quantile(0.5, priority=9) is None
    rows = tr.sketch_rows()
    assert {r["name"] for r in rows} == {"slo_ttft_sketch"}
    assert {r["labels"]["priority"] for r in rows} == {0, 1}
    snap = tr.snapshot()
    assert snap["observed"]["ttft"] == 100
    assert snap["ttft_p50_ms"] is not None


def test_latency_objective_only_feeds_window_when_declared():
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=100.0))
    assert tr.observe_latency(0.0, 99.0) is False    # no objective
    assert tr.burn_rate(0.0, 1.0, metric="latency") is None
    tr2 = SLOTracker(SLOPolicy(ttft_objective_ms=100.0,
                               latency_objective_ms=50.0))
    assert tr2.observe_latency(0.0, 0.2) is True
    assert tr2.breaches["latency"] == 1


# ----------------------------------------------------------------------------
# the burn-rate autoscale signal
# ----------------------------------------------------------------------------

def _signal(**kw):
    from repro.serving.autoscaler import AutoscalePolicy
    slo = SLOPolicy(ttft_objective_ms=100.0, error_budget=0.1)
    tr = SLOTracker(slo)
    asp = AutoscalePolicy(min_replicas=1, max_replicas=3,
                          high_window_s=0.1, low_window_s=0.2,
                          cooldown_s=kw.pop("cooldown_s", 0.0))
    return tr, SLOSignal(tr, asp, **kw)


def test_slo_signal_scales_out_on_sustained_burn():
    tr, sig = _signal()
    fired = None
    for i in range(30):
        t = i * 0.02
        tr.observe_ttft(t, 0.5)       # every request breaches
        if sig.observe(t, 0, 0, 1) == "out":
            fired = t
            break
    assert fired is not None and fired >= 0.1


def test_slo_signal_cold_cluster_never_scales():
    tr, sig = _signal()
    for i in range(30):
        assert sig.observe(i * 0.02, 99, 2, 1) is None  # queue ignored


def test_slo_signal_scales_in_when_burn_well_under_budget():
    tr, sig = _signal()
    tr.observe_ttft(0.0, 0.01)        # healthy traffic, burn 0
    fired = None
    for i in range(40):
        t = i * 0.02
        if sig.observe(t, 0, 0, 2) == "in":
            fired = t
            break
    assert fired is not None and fired >= 0.2
    # at the floor the same series never scales in
    tr2, sig2 = _signal()
    tr2.observe_ttft(0.0, 0.01)
    for i in range(40):
        assert sig2.observe(i * 0.02, 0, 0, 1) is None


def test_slo_signal_cooldown_and_reset():
    tr, sig = _signal(cooldown_s=0.3)
    fired = []
    for i in range(60):
        t = i * 0.02
        tr.observe_ttft(t, 0.5)
        if sig.observe(t, 0, 0, 1) == "out":
            fired.append(t)
    assert len(fired) >= 2
    assert (np.diff(fired) >= 0.3 - 1e-9).all()
    sig.reset()
    assert sig._above_since is None and sig._last_decision == -math.inf
    with pytest.raises(ValueError):
        SLOSignal(tr, sig.policy, scale_out_burn=0.2, scale_in_burn=0.5)


# ----------------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------------

def test_flight_recorder_ring_bounded_and_dump_valid(tmp_path):
    fr = FlightRecorder(capacity=8)
    for i in range(40):
        fr.append("instant", {"name": f"e{i}", "cat": "step",
                              "t": float(i), "pid": 0, "tid": 0,
                              "args": {}})
    fr.breach(40.0, "ttft_breach", rid=7, ttft_ms=500.0)
    doc = fr.dump(str(tmp_path / "flight.json"))
    assert validate_trace_events(doc) == []
    meta = doc["otherData"]["flight_recorder"]
    assert meta["capacity"] == 8 and meta["events"] == 8
    assert meta["dropped"] == 41 - 8
    assert [a["reason"] for a in meta["anomalies"]] == ["ttft_breach"]
    on_disk = json.loads((tmp_path / "flight.json").read_text())
    assert validate_trace_events(on_disk) == []
    assert fr.dumps == 1
    fr.reset()
    assert fr.appended == 0 and not fr.anomalies


def test_flight_recorder_storm_and_thrash_detectors(tmp_path):
    fr = FlightRecorder(preempt_storm=3, evict_thrash=16, window_s=1.0,
                        dump_path=str(tmp_path / "a.json"))
    fr.note_preempt(0.0)
    fr.note_preempt(0.1)
    assert not fr.anomalies                  # below threshold
    fr.note_preempt(0.2)                     # 3 within the window
    assert [a["reason"] for a in fr.anomalies] == ["preempt_storm"]
    assert (tmp_path / "a.json").exists()    # anomaly triggered a dump
    # detector re-arms: the window cleared on firing
    fr.note_preempt(0.3)
    assert len(fr.anomalies) == 1
    # eviction thrash works on counter deltas (skips resets backwards)
    fr.note_evictions(2.0, 4)
    fr.note_evictions(2.1, 0)                # counter reset: ignored
    fr.note_evictions(2.2, 9)                # delta 9: 4+9 < 16
    assert len(fr.anomalies) == 1
    fr.note_evictions(2.3, 16)               # delta 7: 4+9+7 >= 16
    assert [a["reason"] for a in fr.anomalies] == ["preempt_storm",
                                                   "eviction_thrash"]


def test_observability_feeds_recorder_ring():
    fr = FlightRecorder(capacity=16)
    obs = Observability()
    obs.recorder = fr
    obs.begin_run()
    obs.span(1, "step", "step", 0.0, 1.0)
    obs.instant(1, "evt", "step", 1.5)
    assert fr.appended == 2
    doc = fr.to_perfetto()
    assert validate_trace_events(doc) == []
    names = {e["name"] for e in doc["traceEvents"]
             if e.get("ph") in ("X", "i")}
    assert {"step", "evt"} <= names


# ----------------------------------------------------------------------------
# diurnal workload
# ----------------------------------------------------------------------------

def test_diurnal_reproducible_ordered_and_actually_diurnal():
    kw = dict(vocab_size=100, rate_min=1.0, rate_max=100.0, period=4.0,
              priorities=(0, 1))
    a = diurnal_requests(200, seed=7, **kw)
    b = diurnal_requests(200, seed=7, **kw)
    assert all(np.array_equal(x.prompt, y.prompt) and
               x.arrival == y.arrival and x.priority == y.priority
               for x, y in zip(a, b))
    arr = np.array([r.arrival for r in a])
    assert (np.diff(arr) > 0).all()
    # the sinusoid starts at the trough and peaks at period/2: far more
    # arrivals land in the mid-cycle half than in the edges
    phase = arr % 4.0
    mid = ((phase > 1.0) & (phase < 3.0)).sum()
    edge = len(arr) - mid
    assert mid > 2 * edge
    c = diurnal_requests(200, seed=8, **kw)
    assert any(x.arrival != y.arrival for x, y in zip(a, c))


def test_diurnal_weights_and_validation():
    reqs = diurnal_requests(16, vocab_size=50, priorities=(0, 3),
                            priority_weights=(0.0, 1.0), seed=0)
    assert all(r.priority == 3 for r in reqs)
    with pytest.raises(ValueError):
        diurnal_requests(4, vocab_size=50, rate_min=0.0)
    with pytest.raises(ValueError):
        diurnal_requests(4, vocab_size=50, rate_min=5.0, rate_max=1.0)
    with pytest.raises(ValueError):
        diurnal_requests(4, vocab_size=50, segments=1)
    with pytest.raises(ValueError):
        diurnal_requests(4, vocab_size=50, priorities=(0, 1),
                         priority_weights=(1.0,))


# ----------------------------------------------------------------------------
# schema: v2 + sketches + flight-recorder blocks, v1 back-compat
# ----------------------------------------------------------------------------

def test_metrics_schema_v2_and_v1_back_compat():
    assert METRICS_SCHEMA.endswith("/v2")
    assert len(METRICS_SCHEMAS) == 2
    base = {"counters": [], "gauges": [], "histograms": [], "series": []}
    for schema in METRICS_SCHEMAS:           # both generations validate
        assert validate_metrics_dump({"schema": schema, **base}) == []
    assert validate_metrics_dump({"schema": "repro.serving.metrics/v3",
                                  **base}) != []
    good_sketch = {"name": "slo_ttft_sketch", "labels": {"priority": 0},
                   "rel_err": 0.01, "min_value": 1e-5, "max_value": 3600.0,
                   "count": 3, "sum": 1.5, "buckets": [[4, 1], [9, 2]]}
    doc = {"schema": METRICS_SCHEMA, **base, "sketches": [good_sketch]}
    assert validate_metrics_dump(doc) == []
    for corrupt in ({"rel_err": 1.5}, {"count": -1}, {"buckets": [[1]]},
                    {"buckets": [[0, 5]]},    # counts no longer sum
                    {"name": 7}, {"labels": "x"}):
        bad = {**good_sketch, **corrupt}
        assert validate_metrics_dump(
            {"schema": METRICS_SCHEMA, **base, "sketches": [bad]}) != []
    assert validate_metrics_dump(
        {"schema": METRICS_SCHEMA, **base, "slo": "not-a-dict"}) != []


def test_trace_flight_recorder_block_validation():
    base = {"displayTimeUnit": "ms", "otherData": {},
            "traceEvents": []}
    good = {**base, "otherData": {
        "flight_recorder": {"capacity": 8, "events": 3, "dropped": 0,
                            "anomalies": [{"t": 1.0,
                                           "reason": "ttft_breach",
                                           "args": {}}]}}}
    assert validate_trace_events(good) == []
    for corrupt in ({"capacity": -1}, {"events": "x"},
                    {"anomalies": [{"t": "late"}]},
                    {"anomalies": [{"reason": 7, "t": 0.0}]}):
        bad = {**base, "otherData": {"flight_recorder": {
            "capacity": 8, "events": 0, "dropped": 0, "anomalies": [],
            **corrupt}}}
        assert validate_trace_events(bad) != []


def test_sampling_params_deadline_validation():
    assert SamplingParams().deadline_ms is None
    assert SamplingParams(deadline_ms=250.0).deadline_ms == 250.0
    with pytest.raises(ValueError):
        SamplingParams(deadline_ms=0.0)
    with pytest.raises(ValueError):
        SamplingParams(deadline_ms=-10.0)


# ----------------------------------------------------------------------------
# end-to-end: shed/defer on a real engine + the bit-identity gate
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


KW = dict(num_slots=2, block_size=8, max_seq_len=48, prefill_max_batch=2)


def _reqs(cfg, n=8, deadline_ms=None, seed=0):
    reqs = synthetic_requests(n, vocab_size=cfg.vocab_size,
                              prompt_len=(8, 16), max_new=(3, 6),
                              seed=seed)
    if deadline_ms is not None:
        for i, r in enumerate(reqs):
            d = deadline_ms[i] if isinstance(deadline_ms, (list, tuple)) \
                else deadline_ms
            r.sampling = SamplingParams(deadline_ms=d)
    return reqs


def test_slo_engine_bit_identity_when_nothing_sheds(tiny):
    """The universal gate, SLO edition: tracker on, shedder ARMED,
    recorder on, generous deadlines — outputs must be bit-identical to
    the plain engine."""
    params, cfg = tiny
    base = ServingEngine(params, cfg, **KW)
    want = {c.rid: c.tokens.tolist() for c in base.run(_reqs(cfg))}
    obs = Observability(recorder=FlightRecorder())
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=100.0))
    eng = ServingEngine(params, cfg, obs=obs, slo_tracker=tr,
                        slo_shed=True, **KW)
    done = eng.run(_reqs(cfg, deadline_ms=60000.0))
    assert {c.rid: c.tokens.tolist() for c in done} == want
    assert eng.scheduler.shed_requests == 0
    assert tr.snapshot()["observed"]["ttft"] == len(want)
    # the tracker saw completions too: tpot for every multi-token one
    assert tr.snapshot()["observed"]["latency"] == len(want)


def test_slo_engine_sheds_hopeless_deadlines(tiny):
    params, cfg = tiny
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=50.0))
    eng = ServingEngine(params, cfg, slo_tracker=tr, slo_shed=True, **KW)
    # alternate generous / impossible deadlines: the impossible ones
    # shed (zero tokens, finish_reason 'shed'), the rest decode whole
    deadlines = [60000.0 if i % 2 == 0 else 0.01 for i in range(10)]
    done = eng.run(_reqs(cfg, n=10, deadline_ms=deadlines))
    shed = [c for c in done if c.finish_reason == "shed"]
    kept = [c for c in done if c.finish_reason != "shed"]
    assert len(done) == 10 and len(shed) >= 1
    assert eng.scheduler.shed_requests == len(shed)
    assert all(len(c.tokens) == 0 and c.t_done >= c.arrival for c in shed)
    assert all(len(c.tokens) > 0 for c in kept)
    stats = summarize(done, eng.wall_time, eng)
    assert stats["requests"] == len(kept)
    assert stats["shed_requests"] == len(shed)
    assert stats["slo"]["shed_requests"] == len(shed)


def test_slo_admission_defers_by_slack_without_changing_tokens(tiny):
    """Deadline-slack ordering inside a priority class reorders
    admission (deferral telemetry) but — batch-composition
    independence — never changes any request's tokens."""
    params, cfg = tiny
    base = ServingEngine(params, cfg, **KW)
    want = {c.rid: c.tokens.tolist() for c in base.run(_reqs(cfg))}
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=100.0))
    eng = ServingEngine(params, cfg, slo_tracker=tr, slo_shed=True, **KW)
    # all generous (nothing sheds) but strictly REVERSED slack order:
    # the baseline FCFS order inverts, so every non-tightest request
    # slips behind its deadline-blind position at least once
    deadlines = [60000.0 - 1000.0 * i for i in range(8)]
    done = eng.run(_reqs(cfg, deadline_ms=deadlines))
    assert {c.rid: c.tokens.tolist() for c in done} == want
    assert eng.scheduler.shed_requests == 0
    assert eng.scheduler.deferrals >= 1


def test_slo_metrics_dump_carries_sketches(tiny):
    params, cfg = tiny
    obs = Observability()
    tr = SLOTracker(SLOPolicy(ttft_objective_ms=100.0))
    eng = ServingEngine(params, cfg, obs=obs, slo_tracker=tr, **KW)
    obs.slo = tr
    eng.run(_reqs(cfg))
    doc = metrics_dump(obs)
    assert validate_metrics_dump(doc) == []
    assert {r["name"] for r in doc["sketches"]} >= {"slo_ttft_sketch"}
    assert doc["slo"]["observed"]["ttft"] == 8
    gauges = {g["name"] for g in doc["gauges"]}
    assert {"slo_burn_rate_fast_gauge", "slo_burn_rate_slow_gauge"} \
        <= gauges


def test_diurnal_workload_runs_through_engine(tiny):
    params, cfg = tiny
    reqs = diurnal_requests(6, vocab_size=cfg.vocab_size, rate_min=50.0,
                            rate_max=400.0, period=0.5, prompt_len=(8, 12),
                            max_new=(2, 4), seed=0)
    eng = ServingEngine(params, cfg, **KW)
    done = eng.run(reqs)
    assert len(done) == 6 and all(len(c.tokens) > 0 for c in done)
