"""Per-request sampling API: SamplingParams resolution, logit warping,
position-keyed batch-composition invariance (unit + engine e2e),
distribution-preserving speculative sampling (tiny-vocab frequency
test), unified stop handling incl. mid-speculative-chain truncation,
streaming, logprobs, and the deprecation shim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving import sampling
from repro.serving.block_manager import BlockAllocator
from repro.serving.bucketing import chain_buckets, pick_bucket, pow2_buckets
from repro.serving.engine import (Request, ServingEngine, summarize,
                                  synthetic_requests)
from repro.serving.sampling import SamplingParams, resolve
from repro.serving.scheduler import Scheduler

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------------
# SamplingParams: validation, stop normalization, legacy-field resolution
# ----------------------------------------------------------------------------

def test_sampling_params_validation_and_stop_normalization():
    sp = SamplingParams(temperature=0.7, top_k=5, top_p=0.9, stop=[3, (4, 5)])
    assert sp.stop == ((3,), (4, 5))
    assert SamplingParams(stop=7).stop == ((7,),)
    assert SamplingParams().greedy and not sp.greedy
    assert sp.with_seed(9).seed == 9 and sp.seed == 0     # frozen
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(max_new_tokens=0),
                dict(stop=[()])):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_resolve_merges_legacy_fields():
    default = SamplingParams(temperature=0.5, seed=4)
    # request sampling wins over the engine default
    sp = resolve(SamplingParams(temperature=0.9), default)
    assert sp.temperature == 0.9
    # no request sampling: the engine default applies
    assert resolve(None, default).temperature == 0.5
    # legacy max_new_tokens overrides the config's cap
    assert resolve(None, default, max_new_tokens=3).max_new_tokens == 3
    # legacy eos_id becomes one more single-token stop (deduplicated)
    sp = resolve(SamplingParams(stop=[2]), None, eos_id=9)
    assert sp.stop == ((2,), (9,))
    assert resolve(SamplingParams(stop=[9]), None, eos_id=9).stop == ((9,),)


def test_seed32_folds_any_int():
    assert sampling.seed32(0) == 0 and sampling.seed32(7) == 7
    assert sampling.seed32(2**40 + 3) == sampling.seed32(3)
    assert sampling.seed32(-1) == sampling.seed32(0xFFFFFFFF)


# ----------------------------------------------------------------------------
# warp_logits: temperature / top-k / top-p
# ----------------------------------------------------------------------------

def test_warp_logits_topk_topp():
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0, 0.0]])
    one = jnp.ones(1)
    w = sampling.warp_logits(x, one, jnp.asarray([2]), one)
    np.testing.assert_array_equal(np.isfinite(np.asarray(w[0])),
                                  [False, False, True, True, False])
    # probs are ~[.03, .09, .23, .64, .01]: a 0.6 nucleus is {3} alone,
    # 0.7 needs {3, 2}
    w = sampling.warp_logits(x, one, jnp.asarray([0]), jnp.asarray([0.6]))
    assert np.isfinite(np.asarray(w[0])).sum() == 1
    w = sampling.warp_logits(x, one, jnp.asarray([0]), jnp.asarray([0.7]))
    np.testing.assert_array_equal(np.isfinite(np.asarray(w[0])),
                                  [False, False, True, True, False])
    # top_p=1 and top_k=0 are exact no-ops; temperature rescales
    w = sampling.warp_logits(x, one, jnp.asarray([0]), one)
    np.testing.assert_allclose(np.asarray(w), np.asarray(x))
    w = sampling.warp_logits(x, 2.0 * one, jnp.asarray([0]), one)
    np.testing.assert_allclose(np.asarray(w), np.asarray(x) / 2.0)
    # per-row configs are independent (config-as-data batching)
    xb = jnp.stack([x[0], x[0]])
    w = sampling.warp_logits(xb, jnp.ones(2), jnp.asarray([2, 0]),
                             jnp.asarray([1.0, 0.6]))
    assert np.isfinite(np.asarray(w[0])).sum() == 2
    assert np.isfinite(np.asarray(w[1])).sum() == 1


def test_sample_tokens_batch_invariant_and_greedy():
    logits = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 16))
    temps = jnp.asarray([0.0, 0.8, 1.2])
    topks = jnp.asarray([0, 4, 0])
    topps = jnp.asarray([1.0, 1.0, 0.9])
    seeds = jnp.asarray([0, 11, 11])
    pos = jnp.asarray([5, 5, 9])
    tok, lp = sampling.sample_tokens(logits, pos, temps, topks, topps,
                                     seeds)
    assert int(tok[0]) == int(jnp.argmax(logits[0]))
    # each sampled lane reproduces bit-identically when run ALONE —
    # the draw depends only on (seed, position), not on batch mates
    for b in (1, 2):
        solo, _ = sampling.sample_tokens(
            logits[b:b + 1], pos[b:b + 1], temps[b:b + 1], topks[b:b + 1],
            topps[b:b + 1], seeds[b:b + 1])
        assert int(solo[0]) == int(tok[b])
    # same seed, different position -> a fresh draw stream
    tok2, _ = sampling.sample_tokens(logits, pos + 1, temps, topks, topps,
                                     seeds)
    assert np.asarray(lp).max() <= 0.0
    assert tok.dtype == jnp.int32 and tok2.shape == tok.shape


# ----------------------------------------------------------------------------
# verify_tokens: greedy accept rule + distribution preservation
# ----------------------------------------------------------------------------

def test_verify_tokens_greedy_matches_argmax_accept():
    V, T = 8, 4
    logits = jax.random.normal(jax.random.fold_in(KEY, 2), (2, T, V))
    am = np.asarray(jnp.argmax(logits, -1))
    # lane 0: drafts agree with argmax at chain idx 1,2 then diverge;
    # lane 1: first draft already disagrees
    chain = np.zeros((2, T), np.int32)
    chain[0] = [3, am[0, 0], am[0, 1], (am[0, 2] + 1) % V]
    chain[1] = [2, (am[1, 0] + 1) % V, 0, 0]
    counts = jnp.asarray([4, 2], jnp.int32)
    emit, acc, lp = sampling.verify_tokens(
        logits, jnp.asarray(chain), counts, jnp.asarray([7, 9]),
        jnp.zeros(2), jnp.zeros(2, jnp.int32), jnp.ones(2),
        jnp.zeros(2, jnp.int32))
    assert list(np.asarray(acc)) == [2, 0]
    np.testing.assert_array_equal(np.asarray(emit), am)   # greedy emits
    assert np.asarray(lp).max() <= 0.0


def _spec_marginal(row_logits, draft_tok, temps, topk, topp, n=16384):
    """Empirical marginal of the token verify_tokens emits at chain
    index 0, over n per-request seeds (the tiny-vocab frequency test)."""
    V = row_logits.shape[-1]
    logits = jnp.broadcast_to(row_logits[None, None], (n, 2, V))
    chain = jnp.broadcast_to(jnp.asarray([[1, draft_tok]]),
                             (n, 2)).astype(jnp.int32)
    emit, acc, _ = jax.jit(sampling.verify_tokens)(
        logits, chain, jnp.full((n,), 2, jnp.int32),
        jnp.full((n,), 13, jnp.int32), jnp.full((n,), temps),
        jnp.full((n,), topk, jnp.int32), jnp.full((n,), topp),
        jnp.arange(n, dtype=jnp.int32))
    freq = np.bincount(np.asarray(emit[:, 0]), minlength=V) / n
    return freq, np.asarray(acc)


def test_speculative_sampling_preserves_marginal_tiny_vocab():
    """Leviathan accept/reject with a deterministic draft must leave the
    next-token marginal exactly the target distribution: accept d w.p.
    q(d), else resample from q with d masked — marginal q. Checked by
    frequency over 16k independent per-request seeds, draft inside and
    OUTSIDE the nucleus, warped and unwarped."""
    V = 8
    row = jax.random.normal(jax.random.fold_in(KEY, 3), (V,))
    temp = 0.9
    q = np.asarray(jax.nn.softmax(row / temp))
    # draft = a mid-probability token, no warping
    d = int(np.argsort(q)[V // 2])
    freq, acc = _spec_marginal(row, d, temp, 0, 1.0)
    assert 0.5 * np.abs(freq - q).sum() < 0.03
    assert abs(acc.astype(bool).mean() - q[d]) < 0.02   # accept w.p. q(d)
    # draft OUTSIDE the top-k: q_k(d) = 0, every draft rejected, and the
    # marginal is the WARPED target
    wq = np.asarray(jax.nn.softmax(sampling.warp_logits(
        row[None], jnp.asarray([temp]), jnp.asarray([3]),
        jnp.asarray([1.0]))[0]))
    d_out = int(np.argsort(q)[0])
    assert wq[d_out] == 0.0
    freq, acc = _spec_marginal(row, d_out, temp, 3, 1.0)
    assert acc.sum() == 0
    assert 0.5 * np.abs(freq - wq).sum() < 0.03


# ----------------------------------------------------------------------------
# engine e2e: mixed-config batches, batch-composition invariance
# ----------------------------------------------------------------------------

def _expect(params, cfg, req):
    return np.asarray(generate(params, cfg, np.asarray(req.prompt)[None],
                               req.max_new_tokens))[0]


def _mixed_requests(cfg, repetitive=False):
    rng = np.random.default_rng(5)
    if repetitive:
        pat = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
        prompts = [np.tile(pat, 4)[:16] for _ in range(4)]
    else:
        prompts = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
                   for _ in range(4)]
    return [
        Request(rid=0, prompt=prompts[0], max_new_tokens=8),   # greedy
        Request(rid=1, prompt=prompts[1], sampling=SamplingParams(
            temperature=0.8, top_k=32, seed=11, max_new_tokens=9)),
        Request(rid=2, prompt=prompts[2], sampling=SamplingParams(
            temperature=1.2, top_p=0.9, seed=7, max_new_tokens=6)),
        # explicit temperature-0 SamplingParams: must stay bit-identical
        # to generate() through every path, including speculation
        Request(rid=3, prompt=prompts[3], sampling=SamplingParams(
            temperature=0.0, max_new_tokens=7)),
    ]


def test_engine_mixed_batch_and_composition_invariance():
    """One batch serving greedy + sampled + nucleus lanes at once:
    greedy lanes stay bit-identical to generate(), and each sampled
    lane's output is bit-identical when rerun alone or in a different
    mix (same per-request seed) — the position-keyed PRNG contract."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg)
    eng = ServingEngine(params, cfg, num_slots=4, block_size=4,
                        max_seq_len=32)
    done = eng.run(list(reqs))
    out = {c.rid: c.tokens for c in done}
    assert len(done) == 4
    for rid in (0, 3):
        np.testing.assert_array_equal(out[rid],
                                      _expect(params, cfg, reqs[rid]))
    stats = summarize(done, eng.wall_time, eng)
    assert stats["sampling"]["sampled_requests"] == 2
    assert stats["sampling"]["greedy_requests"] == 2
    # rerun each sampled request alone, then in a different mix
    for rid in (1, 2):
        solo = eng.run([dataclasses.replace(reqs[rid], arrival=0.0)])
        np.testing.assert_array_equal(solo[0].tokens, out[rid])
    pair = eng.run([dataclasses.replace(reqs[2], arrival=0.0),
                    dataclasses.replace(reqs[0], arrival=0.0)])
    np.testing.assert_array_equal(
        {c.rid: c.tokens for c in pair}[2], out[2])


def test_engine_spec_sampled_mixed_batch_invariance():
    """Speculation on, mixed greedy/sampled/temp-0 batch: greedy and
    explicit temperature-0 lanes stay bit-identical to generate()
    through the verify path, sampled lanes are batch-composition
    invariant, and pools fully restore."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, repetitive=True)
    eng = ServingEngine(params, cfg, num_slots=4, block_size=4,
                        max_seq_len=32, speculate=4)
    free0 = eng.allocator.num_free
    done = eng.run(list(reqs))
    out = {c.rid: c.tokens for c in done}
    proposed = eng.scheduler.proposed_tokens   # stats reset per run
    assert proposed > 0
    assert eng.allocator.num_free == free0
    for rid in (0, 3):   # greedy + explicit temp-0 SamplingParams
        np.testing.assert_array_equal(out[rid],
                                      _expect(params, cfg, reqs[rid]))
    for rid in (1, 2):
        solo = eng.run([dataclasses.replace(reqs[rid], arrival=0.0)])
        np.testing.assert_array_equal(solo[0].tokens, out[rid])


# ----------------------------------------------------------------------------
# unified stop handling (eos == stop seq; mid-speculative-chain cut)
# ----------------------------------------------------------------------------

class _OracleProposer:
    """Proposes the request's true greedy continuation verbatim, so
    every draft is accepted — drives stops deep into accepted chains."""

    def __init__(self, scripts):
        self.scripts = scripts        # [(prompt list, continuation list)]

    def propose(self, history, k):
        hist = list(history)
        for prompt, out in self.scripts:
            full = prompt + out
            if (len(prompt) <= len(hist) <= len(full)
                    and hist == full[:len(hist)]):
                return full[len(hist):len(hist) + k]
        return []


def _stop_cut_index(full, stop):
    """Earliest end index in `full` where `stop` completes."""
    L = len(stop)
    for end in range(L, len(full) + 1):
        if list(full[end - L:end]) == list(stop):
            return end
    return None


def test_stop_sequence_plain_decode_and_multi_token():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompt, 10))[0]
    stop = (int(full[2]), int(full[3]))          # multi-token stop
    cut = _stop_cut_index(full, stop)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32)
    done = eng.run([Request(rid=0, prompt=np.asarray(prompt[0]),
                            sampling=SamplingParams(
                                max_new_tokens=10, stop=(stop,)))])
    assert done[0].finish_reason == "stop"
    np.testing.assert_array_equal(done[0].tokens, full[:cut])
    # no stop hit -> length finish
    done = eng.run([Request(rid=1, prompt=np.asarray(prompt[0]),
                            max_new_tokens=4)])
    assert done[0].finish_reason == "length"


def test_stop_sequence_mid_speculative_chain_frees_blocks():
    """A stop completing inside an ACCEPTED draft chain must truncate
    the output exactly at the stop, and the chain's claimed-but-unused
    blocks must all return to the pool (accepted prefix truncates,
    rejected/cut tail frees its claims)."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompt, 12))[0]
    stop = (int(full[4]), int(full[5]))
    cut = _stop_cut_index(full, stop)
    assert cut is not None and cut >= 2
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32, speculate=6)
    script = [([int(t) for t in prompt[0]], [int(t) for t in full])]
    eng.scheduler._proposers = [_OracleProposer(script)] * 2
    free0 = eng.allocator.num_free
    done = eng.run([Request(rid=0, prompt=np.asarray(prompt[0]),
                            sampling=SamplingParams(
                                max_new_tokens=12, stop=(stop,)))])
    assert done[0].finish_reason == "stop"
    np.testing.assert_array_equal(done[0].tokens, full[:cut])
    assert eng.allocator.num_free == free0       # chain claims all freed
    # the oracle drafted past the stop: some drafts were cut, so
    # accepted < proposed even though every draft agreed
    assert eng.scheduler.accepted_tokens < eng.scheduler.proposed_tokens


def test_eos_and_stop_are_one_code_path():
    """Legacy eos_id resolves into the unified stop list and behaves
    exactly like a one-token stop sequence."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompt, 8))[0]
    eos = int(full[3])
    cut = _stop_cut_index(full, (eos,))
    eng = ServingEngine(params, cfg, num_slots=1, block_size=4,
                        max_seq_len=32)
    legacy = eng.run([Request(rid=0, prompt=np.asarray(prompt[0]),
                              max_new_tokens=8, eos_id=eos)])
    new = eng.run([Request(rid=1, prompt=np.asarray(prompt[0]),
                           sampling=SamplingParams(max_new_tokens=8,
                                                   stop=(eos,)))])
    np.testing.assert_array_equal(legacy[0].tokens, full[:cut])
    np.testing.assert_array_equal(new[0].tokens, legacy[0].tokens)
    assert legacy[0].finish_reason == new[0].finish_reason == "stop"


# ----------------------------------------------------------------------------
# streaming + logprobs + deprecation shim
# ----------------------------------------------------------------------------

def test_stream_matches_run_and_orders_events():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = synthetic_requests(5, vocab_size=cfg.vocab_size, prompt_len=8,
                              max_new=(3, 8), seed=9)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32)
    chunks, finals = {r.rid: [] for r in reqs}, {}
    for ev in eng.stream(list(reqs)):
        if ev.done:
            assert ev.rid not in finals          # done fires once, last
            finals[ev.rid] = ev.completion
        else:
            assert ev.rid not in finals          # no tokens after done
            chunks[ev.rid].extend(ev.tokens)
    assert set(finals) == {r.rid for r in reqs}
    expect = {c.rid: c.tokens for c in eng.run(list(reqs))}
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(chunks[r.rid], np.int32),
                                      expect[r.rid])
        np.testing.assert_array_equal(finals[r.rid].tokens, expect[r.rid])
    assert eng.scheduler.on_event is None        # callback restored


def test_chosen_token_logprobs():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                cfg.vocab_size)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32, speculate=3)
    reqs = [Request(rid=0, prompt=np.asarray(prompt[0]),
                    sampling=SamplingParams(max_new_tokens=6,
                                            logprobs=True)),
            Request(rid=1, prompt=np.asarray(prompt[0]),
                    sampling=SamplingParams(max_new_tokens=6,
                                            temperature=0.9, seed=3,
                                            logprobs=True)),
            Request(rid=2, prompt=np.asarray(prompt[0]),
                    max_new_tokens=6)]
    done = {c.rid: c for c in eng.run(reqs)}
    for rid in (0, 1):
        lp = done[rid].logprobs
        assert lp is not None and lp.shape == (len(done[rid].tokens),)
        assert np.isfinite(lp).all() and (lp <= 0).all()
        # logprobs=True is the back-compat spelling of k=1
        assert done[rid].top_ids.shape == (len(done[rid].tokens), 1)
    assert done[2].logprobs is None              # not requested
    assert done[2].top_ids is None and done[2].top_logprobs is None


def test_top_alternatives_unit():
    logits = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 3, 16))
    ids, lps = sampling.top_alternatives(logits, 5)
    assert ids.shape == (2, 3, 5) and lps.shape == (2, 3, 5)
    ref = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    np.testing.assert_allclose(
        np.asarray(lps), np.take_along_axis(ref, np.asarray(ids), -1),
        rtol=1e-6)
    assert (np.diff(np.asarray(lps), axis=-1) <= 1e-7).all()  # descending
    np.testing.assert_array_equal(np.asarray(ids[..., 0]),
                                  np.argmax(np.asarray(logits), -1))


def test_topk_alternative_logprobs_decode_and_verify_paths():
    """SamplingParams.logprobs=k (satellite): Completion carries the k
    alternative (ids, logprobs) per emitted position, through the plain
    decode path AND the speculative verify path (repetitive prompt so
    chains really verify), for greedy and sampled lanes — and the
    greedy realization is unchanged by asking for them."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    pat = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    prompt = np.tile(pat, 4)[:16]
    for speculate in (0, 3):
        eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                            max_seq_len=32, speculate=speculate)
        reqs = [Request(rid=0, prompt=prompt.copy(),
                        sampling=SamplingParams(max_new_tokens=8,
                                                logprobs=3)),
                Request(rid=1, prompt=prompt.copy(),
                        sampling=SamplingParams(max_new_tokens=8,
                                                temperature=0.9, seed=11,
                                                top_k=4, logprobs=2))]
        done = {c.rid: c for c in eng.run(reqs)}
        if speculate:
            assert eng.scheduler.accepted_tokens > 0   # verify path ran
        g = done[0]
        assert g.top_ids.shape == (8, 3)
        assert g.top_logprobs.shape == (8, 3)
        # greedy chosen token IS the top-1 alternative, logprob matches,
        # alternatives sorted descending
        np.testing.assert_array_equal(g.tokens, g.top_ids[:, 0])
        np.testing.assert_allclose(g.logprobs, g.top_logprobs[:, 0],
                                   rtol=1e-5)
        assert (np.diff(g.top_logprobs, axis=1) <= 1e-6).all()
        np.testing.assert_array_equal(
            g.tokens, _expect(params, cfg, reqs[0]))   # output unchanged
        s = done[1]
        assert s.top_ids.shape == (8, 2)
        # a sampled token need not be the argmax, but its RAW-dist
        # logprob can never exceed the top alternative's
        assert (s.logprobs <= s.top_logprobs[:, 0] + 1e-6).all()
    # streaming carries the same alternatives the completion records
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32, speculate=3)
    got_ids, final = [], None
    for ev in eng.stream([Request(rid=0, prompt=prompt.copy(),
                                  sampling=SamplingParams(
                                      max_new_tokens=8, logprobs=3))]):
        if ev.done:
            final = ev.completion
        else:
            assert len(ev.top_ids) == len(ev.tokens)
            got_ids.extend(ev.top_ids)
    np.testing.assert_array_equal(np.asarray(got_ids, np.int32),
                                  final.top_ids)


def test_logprobs_validation_and_cap():
    with pytest.raises(ValueError):
        SamplingParams(logprobs=-1)
    assert SamplingParams(logprobs=True).logprobs == 1
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, num_slots=1, block_size=4,
                        max_seq_len=32, max_logprobs=4)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                           sampling=SamplingParams(max_new_tokens=2,
                                                   logprobs=5)))
    done = eng.run([Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                            sampling=SamplingParams(max_new_tokens=2,
                                                    logprobs=4))])
    assert done[0].top_ids.shape == (2, 4)


def test_engine_deprecation_shim_and_default_sampling():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    with pytest.warns(DeprecationWarning):
        eng = ServingEngine(params, cfg, num_slots=1, block_size=4,
                            max_seq_len=32, temperature=0.7, seed=3)
    assert eng.default_sampling.temperature == 0.7
    assert eng.default_sampling.seed == 3
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert len(done[0].tokens) == 5              # shim still serves
    # an engine-default SamplingParams applies to sampling-less requests
    # and matches the per-request spelling bit-for-bit
    eng2 = ServingEngine(params, cfg, num_slots=1, block_size=4,
                         max_seq_len=32,
                         sampling=SamplingParams(temperature=0.7, seed=3))
    done2 = eng2.run([Request(rid=0, prompt=prompt.copy(),
                              max_new_tokens=5)])
    np.testing.assert_array_equal(done2[0].tokens, done[0].tokens)
    done3 = eng2.run([Request(rid=1, prompt=prompt.copy(),
                              sampling=SamplingParams(temperature=0.7,
                                                      seed=3,
                                                      max_new_tokens=5))])
    np.testing.assert_array_equal(done3[0].tokens, done[0].tokens)
    # identical prompts under a sampled engine DEFAULT draw distinct
    # streams (per-request seed = default.seed + rid): best-of-n over a
    # shared prompt must not collapse to n copies — but each stream is
    # still reproducible (rerun alone matches, seeds stay per-request)
    pair = eng2.run([Request(rid=0, prompt=prompt.copy(),
                             max_new_tokens=5),
                     Request(rid=1, prompt=prompt.copy(),
                             max_new_tokens=5)])
    t = {c.rid: c.tokens for c in pair}
    assert not np.array_equal(t[0], t[1])
    solo = eng2.run([Request(rid=1, prompt=prompt.copy(),
                             max_new_tokens=5)])
    np.testing.assert_array_equal(solo[0].tokens, t[1])


# ----------------------------------------------------------------------------
# property: rejected SAMPLED drafts restore allocator pools exactly
# ----------------------------------------------------------------------------

class _FakeRunner:
    """Host-only runner stand-in (block accounting needs no device)."""

    prefill_max_batch = 4
    max_logprobs = 8

    def __init__(self, speculate=8):
        self.prefill_buckets = pow2_buckets(64, start=8)
        self.verify_buckets = chain_buckets(speculate)

    def suffix_bucket(self, n):
        return pick_bucket(n, self.prefill_buckets)

    def chain_bucket(self, n):
        return pick_bucket(n, self.verify_buckets)

    def prefill(self, rows):
        return (np.full(len(rows), 1, np.int32),
                np.zeros(len(rows), np.float32), None)

    def verify(self, tokens, positions, counts):
        return (np.full(tokens.shape, -1, np.int32),
                np.zeros(tokens.shape[0], np.int32),
                np.zeros(tokens.shape, np.float32), None)

    def commit(self, idx):
        pass

    def copy_block(self, src, dst):
        pass

    def write_table(self, slot, row):
        pass

    def clear_table(self, slot):
        pass

    def set_sampling(self, slot, sp):
        pass

    def clear_sampling(self, slot):
        pass


def _alloc_snapshot(alloc):
    return (alloc.num_free, alloc.num_cached, dict(alloc._ref))


@settings(max_examples=60, deadline=None)
@given(plen=st.integers(1, 18), max_new=st.integers(4, 40),
       consumed=st.integers(0, 8), k=st.integers(1, 8),
       bs=st.integers(2, 5), seed=st.integers(0, 2**34))
def test_rejected_sampled_draft_restores_pools(plen, max_new, consumed,
                                               k, bs, seed):
    """Property (satellite): a SAMPLED lane whose entire draft chain is
    rejected through the real prepare_verify/consume_verify path must
    leave the allocator (refcounts, free list, pools) and the global
    reserved budget exactly as a single-token advance would have —
    every block the chain claimed beyond the advance comes back."""
    if plen + max_new > 64:
        max_new = 64 - plen
        if max_new < 4:
            return
    consumed = min(consumed, max_new - 3)
    alloc = BlockAllocator(72, block_size=bs)
    sched = Scheduler(alloc, _FakeRunner(), num_slots=2, block_size=bs,
                      max_blocks_per_seq=-(-64 // bs), max_seq_len=64,
                      prefix_cache=False, now_fn=lambda: 0.0, speculate=8)
    sched.submit(Request(rid=0, prompt=np.arange(plen, dtype=np.int32),
                         sampling=SamplingParams(temperature=0.9,
                                                 seed=seed,
                                                 max_new_tokens=max_new)))
    sched.admit()
    s = sched._slots[0]
    assert s is not None and not s.sp.greedy
    for _ in range(consumed):             # walk to a reachable position
        sched._claim_blocks(0, s.pos)
        s.pos += 1
    sched._claim_blocks(0, s.pos)
    k_eff = min(k, max_new - len(s.out) - consumed - 1)
    if k_eff <= 0:
        return
    sched._proposers = [type("P", (), {
        "propose": staticmethod(lambda h, kk: [3] * min(kk, k_eff))})()] * 2
    pre = (_alloc_snapshot(alloc), s.budget + 0, s.n_blocks,
           sched._reserved_budget)
    batch = sched.prepare_verify()
    assert batch is not None
    tokens, positions, counts, active = batch
    out = np.full(tokens.shape, -1, np.int32)        # full rejection
    sched.consume_verify(active, out, np.zeros(tokens.shape[0], np.int32))
    assert sched._slots[0] is s                      # still live
    # the single advanced (bonus) token may legitimately keep one
    # claimed block; everything past it must be back in the pool
    keep = max((s.pos - 1) // bs + 1, s.prompt_blocks)
    assert s.n_blocks == keep
    grew = s.n_blocks - pre[2]
    assert _alloc_snapshot(alloc)[0] == pre[0][0] - grew
    assert s.budget == pre[1] - grew
    assert sched._reserved_budget == pre[3] - grew
