"""Model-level invariants: causality, decode/prefill equivalence, dtype."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import lm

settings.register_profile("model_ci", max_examples=5, deadline=None)
settings.load_profile("model_ci")

ARCHS_CAUSAL = ["smollm-135m", "rwkv6-3b", "recurrentgemma-2b",
                "grok-1-314b", "musicgen-medium"]


def _toks(cfg, key, B, S):
    if cfg.frontend == "audio":
        return jax.random.randint(key, (B, S, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS_CAUSAL)
def test_causality(arch):
    """Changing FUTURE tokens must not change past logits — the core
    correctness property of every mixer (attention mask, rwkv scan order,
    rg-lru recurrence, rolling local-attention cache)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S, cut = 2, 24, 13
    toks = _toks(cfg, jax.random.PRNGKey(1), B, S)
    toks2 = toks.at[:, cut:].set(
        _toks(cfg, jax.random.PRNGKey(2), B, S)[:, cut:])
    batch1 = {"tokens": toks, "targets": toks}
    batch2 = {"tokens": toks2, "targets": toks2}
    l1, _ = lm.forward(params, cfg, batch1)
    l2, _ = lm.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(l1[:, :cut], np.float32),
                               np.asarray(l2[:, :cut], np.float32),
                               atol=1e-4, rtol=1e-4)
    # and the change IS visible after the cut (model isn't degenerate)
    assert float(jnp.abs(l1[:, cut:] - l2[:, cut:]).max()) > 1e-4


@pytest.mark.parametrize("arch", ["smollm-135m", "grok-1-314b",
                                  "musicgen-medium"])
def test_decode_matches_prefill_attention_archs(arch):
    """KV-cache decode must reproduce the parallel forward exactly
    (attention-arch counterpart of the recurrent-arch test). MoE archs use
    a high capacity factor so no token is dropped — capacity-based routing
    otherwise differs between prefill (whole sequence competes for slots)
    and decode (fresh buffer per step): the known train/serve discrepancy
    of capacity-routed MoE, documented in DESIGN.md §4."""
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    toks = _toks(cfg, jax.random.PRNGKey(3), B, S)
    logits_seq, _ = lm.forward(params, cfg,
                               {"tokens": toks, "targets": toks})
    state = lm.init_decode_state(cfg, B, max_len=16)
    outs = []
    for pos in range(S):
        tok = toks[:, pos]
        lg, state = lm.decode_step(params, cfg, state, tok, jnp.int32(pos))
        outs.append(lg)
    logits_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_seq, np.float32),
                               np.asarray(logits_step, np.float32),
                               atol=3e-2, rtol=3e-2)


@given(seed=st.integers(0, 10**6))
def test_loss_permutation_invariance_over_batch(seed):
    """Batch order must not change the mean loss (no cross-example
    leakage through the MoE dispatch or normalization)."""
    cfg = get_config("grok-1-314b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 4, 8
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    perm = jnp.array([2, 0, 3, 1])
    batch_p = {"tokens": toks[perm], "targets": toks[perm]}
    l1, _ = lm.train_loss(params, cfg, batch)
    l2, _ = lm.train_loss(params, cfg, batch_p)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_vlm_prefix_sees_image():
    """Text logits must depend on the vision prefix (prefix-LM wiring)."""
    cfg = get_config("paligemma-3b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    s_text = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, s_text), 0,
                              cfg.vocab_size)
    v1 = jax.random.normal(jax.random.PRNGKey(2),
                           (B, cfg.vision_tokens, cfg.vision_dim))
    v2 = jax.random.normal(jax.random.PRNGKey(3),
                           (B, cfg.vision_tokens, cfg.vision_dim))
    l1, _ = lm.forward(params, cfg, {"tokens": toks, "vision_emb": v1})
    l2, _ = lm.forward(params, cfg, {"tokens": toks, "vision_emb": v2})
    assert float(jnp.abs(l1 - l2).max()) > 1e-4
