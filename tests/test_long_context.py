"""Long-context serving: chunked prefill admission + the streamed /
Pallas paged-prefill attention pair.

Covers the three layers the long-context path spans:
  * kernels/paged_prefill.py vs attention.streamed_paged_attention —
    the Pallas kernel against its pure-JAX lax.scan oracle (interpret
    mode), over ragged starts/lengths, GQA, and sliding windows;
  * chunked admission bit-identity — a prompt longer than every
    prefill bucket, admitted chunk-by-chunk, must emit exactly the
    tokens of (a) the unchunked engine and (b) the token-by-token
    generate() path, across chunk sizes, architectures (including the
    recurrent resume path), prefix-cache on/off, and with tracing on;
  * guards + telemetry — oversized suffixes raise an actionable error
    when chunking is disabled, per-chunk dispatch records land in the
    trace, and peak score-tile bytes stay flat as prompts grow.

A hypothesis property sweep rides along where the package is
installed; the deterministic sweeps above run everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.launch.serve import generate
from repro.models import lm
from repro.models.attention import streamed_paged_attention
from repro.serving.engine import ServingEngine, long_document_requests
from repro.serving.observability import (DISPATCH_TID, NULL_OBS,
                                         Observability)
from repro.serving.scheduler import Request

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def sampled_from(*a, **k):
            return None

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

def _rand(i, shape, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape)
            * scale).astype(jnp.float32)


def _kernel_case(window, *, seed=0, N=3, Ls=16, H=4, KV=2, hd=16, bs=4,
                 M=8, P=20, starts=(0, 7, 20), lengths=(10, 23, 0),
                 attn_chunk=8):
    """One ragged batch through both implementations; compares only the
    rows inside each sequence's real suffix (padding rows carry
    finite garbage in both paths by design)."""
    q = _rand(seed, (N, Ls, H, hd))
    k_suf = _rand(seed + 1, (N, Ls, KV, hd))
    v_suf = _rand(seed + 2, (N, Ls, KV, hd))
    k_pool = _rand(seed + 3, (P, bs, KV, hd))
    v_pool = _rand(seed + 4, (P, bs, KV, hd))
    rng = np.random.default_rng(seed)
    bt = rng.integers(1, P, (N, M)).astype(np.int32)
    st_ = np.minimum(np.asarray(starts, np.int32), M * bs)
    ln = np.asarray(lengths, np.int32)
    pos = st_[:, None] + np.arange(Ls)[None, :].astype(np.int32)

    cache = {"k": k_pool, "v": v_pool}
    oracle = streamed_paged_attention(
        q, k_suf, v_suf, cache, jnp.asarray(bt), jnp.asarray(pos),
        jnp.asarray(st_), jnp.asarray(ln), scale=hd**-0.5,
        attn_chunk=attn_chunk, window=window)
    got = paged_prefill_attention(
        q, k_suf, v_suf, k_pool, v_pool, jnp.asarray(bt),
        jnp.asarray(st_), jnp.asarray(ln), window=window, bq=8,
        interpret=True)
    for n in range(N):
        s = int(np.clip(ln[n] - st_[n], 0, Ls))
        if s == 0:
            continue
        np.testing.assert_allclose(np.asarray(got)[n, :s],
                                   np.asarray(oracle)[n, :s],
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [0, 6])
def test_paged_prefill_kernel_matches_streamed_oracle(window):
    _kernel_case(window)


def test_paged_prefill_kernel_ragged_sweep():
    # varying raggedness: fresh prompts (start 0), resumed chunks
    # (start mid-pool), fully-padded rows, MHA and GQA head layouts
    _kernel_case(0, seed=11, starts=(3, 0, 15), lengths=(19, 16, 31))
    _kernel_case(4, seed=12, H=4, KV=4, starts=(8, 1, 0),
                 lengths=(24, 1, 8))
    _kernel_case(0, seed=13, Ls=8, bs=8, M=4, starts=(16, 2, 0),
                 lengths=(24, 10, 0), attn_chunk=32)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), window=st.sampled_from([0, 3, 6]),
           bs=st.sampled_from([4, 8]), kv=st.sampled_from([2, 4]))
    def test_paged_prefill_kernel_property(seed, window, bs, kv):
        rng = np.random.default_rng(seed)
        M = int(rng.integers(2, 8))
        starts = tuple(int(x) for x in rng.integers(0, M * bs + 1, 3))
        lengths = tuple(min(int(s) + int(g), M * bs + 16)
                        for s, g in zip(starts, rng.integers(0, 17, 3)))
        _kernel_case(window, seed=seed, KV=kv, bs=bs, M=M,
                     starts=starts, lengths=lengths)


# ---------------------------------------------------------------------------
# chunked admission identity
# ---------------------------------------------------------------------------

def _arch_setup(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_chunked(params, cfg, prompt, max_new, *, chunk, buckets,
                 prefix_cache=None, obs=None, num_slots=2, block_size=8):
    eng = ServingEngine(params, cfg, num_slots=num_slots,
                        block_size=block_size,
                        max_seq_len=len(prompt) + max_new + 1,
                        prefill_buckets=buckets, prefill_chunk=chunk,
                        prefix_cache=prefix_cache,
                        obs=obs if obs is not None else NULL_OBS)
    done = eng.run([Request(rid=0, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=max_new)])
    return eng, done[0].tokens


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-3b",
                                  "recurrentgemma-2b"])
@pytest.mark.parametrize("chunk", [32, 48])
def test_chunked_prefill_matches_generate(arch, chunk):
    cfg, params = _arch_setup(arch)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 150).astype(np.int32)
    ref = np.asarray(generate(params, cfg, prompt[None], 6))[0]
    _, got = _run_chunked(params, cfg, prompt, 6, chunk=chunk,
                          buckets=[16, 32])
    np.testing.assert_array_equal(got, ref)


def test_chunked_matches_unchunked_engine_and_prefix_cache():
    cfg, params = _arch_setup("smollm-135m")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 200).astype(np.int32)
    # unchunked: buckets wide enough to take the prompt in one dispatch
    _, ref = _run_chunked(params, cfg, prompt, 8, chunk=None,
                          buckets=[64, 256])
    for cache in (False, True):
        eng, got = _run_chunked(params, cfg, prompt, 8, chunk=64,
                                buckets=[16, 32, 64], prefix_cache=cache)
        np.testing.assert_array_equal(got, ref)
        assert eng.runner.prefill_chunk == 64
    # with the cache warm, a repeat of the same prompt is fully cached
    # (suffix 1) and must admit WITHOUT chunking
    done = eng.run([Request(rid=1, prompt=prompt, max_new_tokens=8)])
    np.testing.assert_array_equal(done[0].tokens, ref)
    assert eng.scheduler.prefix_hit_requests >= 1


def test_chunked_interleaves_with_running_decode():
    """A short request admitted first must keep decoding while a long
    prompt chunks in; both outputs stay bit-identical to generate()."""
    cfg, params = _arch_setup("smollm-135m")
    rng = np.random.default_rng(9)
    long_p = rng.integers(0, cfg.vocab_size, 180).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    eng = ServingEngine(params, cfg, num_slots=4, block_size=8,
                        max_seq_len=256, prefill_buckets=[16, 32, 64],
                        prefill_chunk=64)
    done = eng.run([Request(rid=0, prompt=short_p, max_new_tokens=24),
                    Request(rid=1, prompt=long_p, max_new_tokens=6)])
    by_rid = {c.rid: c for c in done}
    for rid, p in ((0, short_p), (1, long_p)):
        exp = np.asarray(generate(params, cfg, p[None],
                                  by_rid[rid].tokens.shape[0]))[0]
        np.testing.assert_array_equal(by_rid[rid].tokens, exp)
    # the long admission spanned several engine steps; the short lane
    # kept emitting during them (TTFT of rid 0 precedes rid 1's)
    assert by_rid[0].t_first_token < by_rid[1].t_first_token


def test_chunked_prefill_with_speculation():
    """Chunked lanes sit out verify dispatches until admitted; greedy
    output under speculation stays identical to generate()."""
    cfg, params = _arch_setup("smollm-135m")
    rng = np.random.default_rng(5)
    pattern = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    prompt = np.tile(pattern, 25)     # 150 tokens, n-gram friendly
    eng = ServingEngine(params, cfg, num_slots=2, block_size=8,
                        max_seq_len=256, prefill_buckets=[16, 32],
                        prefill_chunk=32, speculate=3)
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=10)])
    exp = np.asarray(generate(params, cfg, prompt[None], 10))[0]
    np.testing.assert_array_equal(done[0].tokens, exp)


# ---------------------------------------------------------------------------
# guards + telemetry
# ---------------------------------------------------------------------------

def test_oversized_prompt_without_chunking_raises_actionable():
    cfg, params = _arch_setup("smollm-135m")
    eng = ServingEngine(params, cfg, num_slots=2, block_size=8,
                        max_seq_len=256, prefill_buckets=[16, 32],
                        prefill_chunk=0)
    prompt = np.arange(100, dtype=np.int32) % cfg.vocab_size
    with pytest.raises(ValueError, match="prefill-chunk"):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    # runner-level guard carries the same guidance
    with pytest.raises(ValueError, match="prefill-chunk"):
        eng.runner.suffix_bucket(100)


def test_chunk_steps_traced_and_identity_with_tracing():
    cfg, params = _arch_setup("smollm-135m")
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, 150).astype(np.int32)
    _, ref = _run_chunked(params, cfg, prompt, 6, chunk=32,
                          buckets=[16, 32])
    obs = Observability()
    eng = ServingEngine(params, cfg, num_slots=2, block_size=8,
                        max_seq_len=256, prefill_buckets=[16, 32],
                        prefill_chunk=32, obs=obs)
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])
    np.testing.assert_array_equal(done[0].tokens, ref)
    steps = [s for s in obs.spans if s["tid"] == DISPATCH_TID
             and s["name"] == "prefill"]
    chunked = [s for s in steps if "chunk" in s["args"]]
    assert len(chunked) >= 2, "multi-chunk admission left no chunk records"
    total = chunked[0]["args"]["chunks_total"]
    assert [s["args"]["chunk"] for s in chunked] == list(range(total))
    assert all(s["args"]["chunks_total"] == total for s in chunked)
    assert all("computed_tokens" in s["args"]
               and "first_dispatch" in s["args"] for s in chunked)
    # resumed chunks are a distinct jit variant: chunk 1's first
    # occurrence is flagged as a first dispatch (compile attribution)
    assert chunked[1]["args"]["first_dispatch"] is True


def test_peak_score_bytes_flat_past_chunk_budget():
    """The memory claim, on the runner's analytic accounting: the peak
    score-tile bytes of the largest prefill dispatch stop growing once
    prompts exceed the chunk budget (sub-linear in prompt length)."""
    cfg, params = _arch_setup("smollm-135m")
    rng = np.random.default_rng(21)
    peaks = {}
    for L in (96, 192, 384):
        prompt = rng.integers(0, cfg.vocab_size, L).astype(np.int32)
        eng, _ = _run_chunked(params, cfg, prompt, 4, chunk=32,
                              buckets=[16, 32])
        peaks[L] = eng.runner.prefill_peak_score_bytes
    assert peaks[96] == peaks[192] == peaks[384], peaks
    assert peaks[384] > 0


def test_long_document_workload_generator():
    reqs = long_document_requests(3, vocab_size=256, prompt_len=(64, 128),
                                  max_new=(4, 8), seed=0)
    assert len(reqs) == 3
    assert all(64 <= len(r.prompt) <= 128 for r in reqs)
    assert all(4 <= r.max_new_tokens <= 8 for r in reqs)
    # deterministic in the seed
    again = long_document_requests(3, vocab_size=256, prompt_len=(64, 128),
                                   max_new=(4, 8), seed=0)
    for a, b in zip(reqs, again):
        np.testing.assert_array_equal(a.prompt, b.prompt)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([32, 48, 64]),
           plen=st.integers(80, 220))
    def test_chunked_identity_property(seed, chunk, plen):
        cfg, params = _arch_setup("smollm-135m")
        rng = np.random.default_rng(seed)
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        ref = np.asarray(generate(params, cfg, prompt[None], 4))[0]
        _, got = _run_chunked(params, cfg, prompt, 4, chunk=chunk,
                              buckets=[16, 32, 64])
        np.testing.assert_array_equal(got, ref)
