"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.svrg_update import svrg_update

KEY = jax.random.PRNGKey(0)


def _rand(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape)
            * scale).astype(dtype)


# ----------------------------------------------------------------------------
# svrg_update
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("n", [17, 256, 4096, 100003])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_svrg_update_sweep(n, dtype):
    args = [_rand(i, (n,), dtype) for i in range(5)]
    out = svrg_update(*args, 0.1, 0.5)
    expect = ref.svrg_update_ref(*args, jnp.asarray(0.1, dtype),
                                 jnp.asarray(0.5, dtype))
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)
    assert out.dtype == dtype


def test_svrg_update_block_sizes():
    args = [_rand(i, (5000,)) for i in range(5)]
    expect = ref.svrg_update_ref(*args, 0.05, 2.0)
    for br in [16, 128, 1024]:
        out = svrg_update(*args, 0.05, 2.0, block_rows=br)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-6)


# ----------------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 64),    # MHA
    (2, 4, 2, 256, 64),    # GQA 2:1
    (1, 8, 1, 128, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype):
    q = _rand(1, (B, H, S, hd), dtype)
    k = _rand(2, (B, KV, S, hd), dtype)
    v = _rand(3, (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, bq=64, bk=64)
    expect = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal_and_blocks():
    q = _rand(1, (1, 2, 192, 64))
    k = _rand(2, (1, 2, 192, 64))
    v = _rand(3, (1, 2, 192, 64))
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    for bq, bk in [(64, 64), (192, 96), (96, 192)]:
        out = flash_attention(q, k, v, causal=False, bq=bq, bk=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_path():
    """The model's chunked attention and the kernel agree (same oracle)."""
    from repro.models.attention import chunked_causal_attention
    B, H, KV, S, hd = 2, 4, 2, 128, 32
    q = _rand(1, (B, S, H, hd))
    k = _rand(2, (B, S, KV, hd))
    v = _rand(3, (B, S, KV, hd))
    model_out = chunked_causal_attention(q, k, v, chunk=32)
    kern_out = flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kern_out.transpose(0, 2, 1, 3)),
                               atol=2e-4, rtol=2e-4)


# ----------------------------------------------------------------------------
# rwkv6
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,T,N", [(1, 1, 64, 16), (2, 3, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(B, H, T, N, dtype):
    r = _rand(1, (B, H, T, N), dtype, 0.5)
    k = _rand(2, (B, H, T, N), dtype, 0.5)
    v = _rand(3, (B, H, T, N), dtype, 0.5)
    w = jax.nn.sigmoid(_rand(4, (B, H, T, N)) * 2).astype(dtype)
    u = _rand(5, (H, N), jnp.float32, 0.1)
    y, s = rwkv6_scan(r, k, v, w, u, tc=32)
    y_ref, s_ref = ref.rwkv6_ref(r, k, v, w, u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=tol, rtol=tol)


def test_rwkv6_chunk_invariance():
    B, H, T, N = 1, 2, 96, 32
    r, k, v = (_rand(i, (B, H, T, N), scale=0.5) for i in range(3))
    w = jax.nn.sigmoid(_rand(7, (B, H, T, N)))
    u = _rand(8, (H, N), scale=0.1)
    outs = [rwkv6_scan(r, k, v, w, u, tc=tc)[0] for tc in (16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------------
# rg-lru
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,C", [(1, 64, 32), (2, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(B, T, C, dtype):
    a = jax.nn.sigmoid(_rand(1, (B, T, C)) * 2).astype(dtype)
    x = _rand(2, (B, T, C), dtype, 0.3)
    y, h = rglru_scan(a, x, tc=32, cb=min(C, 128))
    y_ref, h_ref = ref.rglru_ref(a, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=tol, rtol=tol)


def test_rglru_initial_state_and_chunks():
    B, T, C = 2, 64, 64
    a = jax.nn.sigmoid(_rand(1, (B, T, C)))
    x = _rand(2, (B, T, C), scale=0.3)
    h0 = _rand(3, (B, C))
    y_ref, h_ref = ref.rglru_ref(a, x, h0)
    for tc in (8, 64):
        y, h = rglru_scan(a, x, h0, tc=tc, cb=32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=1e-5, rtol=1e-5)


def test_rwkv6_consistency_with_model_layer():
    """The kernel recurrence matches the model's rwkv_seq inner scan."""
    from repro.configs import get_config
    from repro.models import recurrent
    cfg = get_config("rwkv6-3b").reduced()
    params = recurrent.init_rwkv(jax.random.PRNGKey(0), cfg.d_model,
                                 cfg.n_heads, cfg.head_dim, jnp.float32)
    B, S = 2, 16
    x = _rand(9, (B, S, cfg.d_model), scale=0.2)
    y_model, _ = recurrent.rwkv_seq(params, x, cfg)
    # reproduce via kernel: extract projections identically
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    r, k, v, g, w = recurrent._rwkv_projections(
        params, x, x_prev, cfg.n_heads, cfg.head_dim)
    perm = (0, 2, 1, 3)
    y_kern, _ = rwkv6_scan(r.transpose(perm), k.transpose(perm),
                           v.transpose(perm),
                           w.astype(jnp.float32).transpose(perm),
                           params["bonus_u"].astype(jnp.float32), tc=8)
    y_kern = y_kern.transpose(0, 2, 1, 3).reshape(B, S, -1)
    y_kern = recurrent._rwkv_group_norm(y_kern, params["ln_scale"],
                                        cfg.n_heads, cfg.head_dim) * g
    y_kern = y_kern @ params["w_o"]
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               atol=1e-4, rtol=1e-4)
