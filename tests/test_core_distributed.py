"""Tests for MP-DSVRG (Alg. 1), MP-DANE (Alg. 2) and the baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox, theory
from repro.core.baselines import (run_acc_minibatch_sgd, run_dsvrg_erm,
                                  run_emso, run_minibatch_sgd,
                                  run_single_sgd)
from repro.core.mp_dane import run_mp_dane
from repro.core.mp_dsvrg import run_mp_dsvrg
from repro.core.losses import loss_constants
from repro.data.synthetic import LeastSquaresStream

DIM = 16


@pytest.fixture(scope="module")
def stream():
    return LeastSquaresStream(dim=DIM, noise=0.1, seed=0)


@pytest.fixture(scope="module")
def spec(stream):
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    return theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=DIM)


def test_mp_dsvrg_converges(stream, spec):
    res = run_mp_dsvrg(stream, spec, m=4, b=64, T=8)
    sub = float(stream.population_suboptimality(res.w_avg))
    bound = theory.rate_bound_weakly_convex(spec, 64 * 4, 8, exact=False)
    assert sub <= bound, (sub, bound)


def test_mp_dsvrg_inner_solves_subproblem(stream, spec):
    """With many inner iterations, the inner DSVRG loop must approach the
    exact solution of the union minibatch prox subproblem (eq. 12)."""
    m, b = 4, 64
    key = jax.random.PRNGKey(3)
    Xm, ym = stream.sample_distributed(key, m, b)
    gamma = 2.0
    w_prev = jnp.zeros(DIM)
    exact = prox.exact_lsq_prox(w_prev, Xm, ym, gamma)

    from repro.core.losses import least_squares
    from repro.core.mp_dsvrg import _dsvrg_inner_spmd
    eta = 0.3 / (spec.beta + gamma)
    inner = jax.vmap(
        lambda X, y: _dsvrg_inner_spmd(least_squares(), w_prev, w_prev, X, y,
                                       gamma, eta, p=4, K=40, m=m, lam=0.0),
        axis_name="machines")
    z, _ = inner(Xm, ym)
    f_exact = prox.prox_subproblem_value(exact, w_prev, Xm, ym, gamma)
    f_z = prox.prox_subproblem_value(z[0], w_prev, Xm, ym, gamma)
    assert float(f_z - f_exact) < 1e-3, float(f_z - f_exact)


def test_mp_dsvrg_accounting_matches_theory(stream, spec):
    m, b, T = 4, 64, 4
    res = run_mp_dsvrg(stream, spec, m, b, T)
    K = res.plan.K
    assert res.ledger.comm_rounds == 2 * K * T
    assert res.ledger.peak_memory_vectors == b
    # per-machine ops: K*(b + b/p) per outer step
    assert res.ledger.vector_ops == T * K * (b + b // res.plan.p)


def test_mp_dane_exact_matches_union_prox(stream, spec):
    """With exact local solves + correction, enough DANE iterations converge
    to the exact union-minibatch prox point (quadratic => DANE converges)."""
    m, b = 4, 64
    key = jax.random.PRNGKey(5)
    Xm, ym = stream.sample_distributed(key, m, b)
    gamma = 2.0
    w_prev = jnp.zeros(DIM)
    exact = prox.exact_lsq_prox(w_prev, Xm, ym, gamma)

    from repro.core.losses import least_squares
    from repro.core.mp_dane import _dane_round_spmd
    z = jnp.broadcast_to(w_prev, (m, DIM))
    for k in range(12):
        step = jax.vmap(
            lambda zz, X, y: _dane_round_spmd(
                least_squares(), zz, X, y, w_prev, w_prev, gamma, 0.0, 0.0,
                "exact", jax.random.PRNGKey(k), 0.1, True),
            axis_name="machines")
        z = step(z, Xm, ym)
    np.testing.assert_allclose(np.asarray(z[0]), np.asarray(exact), atol=1e-3)


def test_mp_dane_converges_all_solvers(stream, spec):
    for solver, eta in [("exact", 0.1), ("saga", 0.1), ("prox_svrg", 0.05)]:
        res = run_mp_dane(stream, spec, m=4, b=64, T=8, local_solver=solver,
                          eta_scale=eta)
        sub = float(stream.population_suboptimality(res.w_avg))
        bound = theory.rate_bound_weakly_convex(spec, 64 * 4, 8, exact=False)
        assert sub <= bound, (solver, sub, bound)


def test_emso_single_round_accounting(stream, spec):
    res = run_emso(stream, spec, m=4, b=64, T=4)
    # one-shot averaging: 1 round per outer step
    assert res.ledger.comm_rounds == 4


def test_minibatch_sgd_converges(stream, spec):
    res = run_minibatch_sgd(stream, spec, m=4, b=16, T=64)
    sub = float(stream.population_suboptimality(res.w_avg))
    assert sub < 0.1, sub


def test_minibatch_sgd_degrades_with_huge_minibatch(stream, spec):
    """Figure 3 claim: at huge b (tiny T), MP beats minibatch SGD because
    minibatch SGD cannot exploit minibatch sizes beyond O(sqrt(n))."""
    m, b, T = 4, 2048, 2
    sgd = run_minibatch_sgd(stream, spec, m, b, T)
    mp = run_mp_dane(stream, spec, m, b, T, local_solver="exact")
    sub_sgd = float(stream.population_suboptimality(sgd.w_avg))
    sub_mp = float(stream.population_suboptimality(mp.w_avg))
    assert sub_mp < sub_sgd, (sub_mp, sub_sgd)


def test_acc_minibatch_sgd_converges(stream, spec):
    res = run_acc_minibatch_sgd(stream, spec, m=4, b=32, T=32)
    sub = float(stream.population_suboptimality(res.w_avg))
    assert sub < 0.15, sub


def test_single_sgd_reference(stream, spec):
    res = run_single_sgd(stream, spec, n=4096)
    sub = float(stream.population_suboptimality(res.w_avg))
    assert sub < 0.05, sub


def test_dsvrg_erm_converges(stream, spec):
    res = run_dsvrg_erm(stream, spec, m=4, n=4096, K=20)
    sub = float(stream.population_suboptimality(res.w_avg))
    assert sub < 0.05, sub
    assert res.ledger.peak_memory_vectors == 4096 // 4  # stores its shard


def test_table1_resource_model(spec):
    n, m = 10**6, 16
    r_sgd = theory.table1_resources("acc_minibatch_sgd", spec, n, m)
    r_dsvrg = theory.table1_resources("dsvrg", spec, n, m)
    r_mp = theory.table1_resources("mp_dsvrg", spec, n, m, b=1000)
    r_mp_max = theory.table1_resources("mp_dsvrg", spec, n, m, b=n // m)
    # DSVRG: O(1) comm, full-shard memory
    assert r_dsvrg["communication"] == 1
    assert r_dsvrg["memory"] == n / m
    # MP-DSVRG interpolates: memory = b, comm = n/(mb)
    assert r_mp["memory"] == 1000
    assert r_mp["communication"] == n / (m * 1000)
    # at b_max it matches DSVRG comm/memory (up to logs)
    assert r_mp_max["memory"] == n / m
    assert r_mp_max["communication"] == pytest.approx(1.0)
    # all methods are sample-optimal
    assert r_sgd["samples"] == n


def test_mp_dsvrg_communication_memory_tradeoff(stream, spec):
    """Fig. 1: doubling b halves communication and doubles memory."""
    m, total = 4, 512
    res_small = run_mp_dsvrg(stream, spec, m, b=32, T=total // 32)
    res_large = run_mp_dsvrg(stream, spec, m, b=128, T=total // 128)
    # identical K per Thm 10 (same n) => comm scales as T = n/(mb)
    assert res_small.ledger.comm_rounds > res_large.ledger.comm_rounds
    assert res_small.ledger.peak_memory_vectors < \
        res_large.ledger.peak_memory_vectors
    ratio = res_small.ledger.comm_rounds / res_large.ledger.comm_rounds
    assert ratio == pytest.approx(4.0, rel=0.3)


def test_mp_dane_logistic_beats_sgd_at_large_b(stream, spec):
    """App. E: on logistic loss the large-b advantage of MP-DANE holds."""
    from benchmarks.appendix_e_logistic import LogisticStream
    from repro.core.losses import logistic
    ls = LogisticStream(dim=16, noise=0.0, seed=0)
    lspec = theory.ProblemSpec(L=2.0, beta=0.5, B=2.0, dim=16)
    b, T, m = 512, 1, 4
    mp = run_mp_dane(ls, lspec, m, b, T, K=4, R=1, kappa=0.0,
                     local_solver="prox_svrg", eta_scale=0.3,
                     loss=logistic())
    sgd = run_minibatch_sgd(ls, lspec, m, b, T, loss=logistic())
    assert ls.population_logloss(mp.w_avg) < \
        ls.population_logloss(sgd.w_avg)


def test_elastic_remesh_state():
    from repro.configs import get_config
    from repro.models import lm
    from repro.runtime.elastic import remesh_state
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = remesh_state(params, cfg, mesh, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
