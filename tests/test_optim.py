"""Optimizers, MBProx deep-learning step, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.optim import compression as comp
from repro.optim.optimizers import (Schedule, adamw, clip_by_global_norm,
                                    sgd)


def _quad_problem(seed=0, d=16):
    k = jax.random.PRNGKey(seed)
    A = jax.random.normal(k, (d, d)) / d**0.5
    H = A @ A.T + 0.1 * jnp.eye(d)
    b = jax.random.normal(jax.random.fold_in(k, 1), (d,))

    def loss(params):
        w = params["w"]
        return 0.5 * w @ H @ w - b @ w

    w_star = jnp.linalg.solve(H, b)
    return loss, w_star


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(momentum=0.0), lambda: sgd(momentum=0.9),
    lambda: sgd(momentum=0.9, nesterov=True), lambda: adamw()])
def test_optimizers_minimize_quadratic(make_opt):
    loss, w_star = _quad_problem()
    opt = make_opt()
    params = {"w": jnp.zeros(16)}
    state = opt.init(params)
    lr = 0.1
    grad_fn = jax.jit(jax.grad(loss))
    for _ in range(1500):
        g = grad_fn(params)
        params, state = opt.update(g, state, params, lr)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(w_star),
                               atol=0.05)


def test_sgd_bf16_params_stay_bf16():
    opt = sgd(momentum=0.9)
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = opt.init(params)
    g = {"w": jnp.ones(8, jnp.float32)}
    params, state = opt.update(g, state, params, jnp.float32(0.1))
    assert params["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(gn), (800.0) ** 0.5, rtol=1e-5)


def test_schedule():
    s = Schedule(peak=1.0, warmup=10, total=100, floor=0.1)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-5)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-3)
    assert float(s(55)) < float(s(20))


# ----------------------------------------------------------------------------
# MBProx deep-learning step
# ----------------------------------------------------------------------------

def test_mbprox_step_solves_prox_subproblem():
    """With many inner passes and gamma, the local variant approaches the
    prox point of the quadratic loss (single machine => pmean is identity)."""
    from repro.optim.mbprox import MBProxConfig, make_mbprox_step
    from repro.launch.mesh import make_host_mesh
    loss_fn_inner, w_star = _quad_problem()

    def loss_fn(params, micro):
        return loss_fn_inner(params) * micro["scale"][0], {}

    mesh = make_host_mesh()
    gamma = 0.5
    mp = MBProxConfig(gamma=gamma, inner_momentum=0.0, inner_passes=50,
                      dane_correction=False, variant="local")
    step = make_mbprox_step(loss_fn, mp, mesh, ("data",))
    params = {"w": jnp.zeros(16)}
    batch = {"scale": jnp.ones((4, 1))}
    with compat.set_mesh(mesh):
        new_p, _, m = jax.jit(step)(params, (), batch, jnp.float32(0.05))
    # prox point: argmin loss + gamma/2 ||w||^2 = (H + gamma I)^{-1} b
    loss, _ = _quad_problem()
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (16, 16)) / 4.0
    H = A @ A.T + 0.1 * jnp.eye(16)
    b = jax.random.normal(jax.random.fold_in(k, 1), (16,))
    expect = jnp.linalg.solve(H + gamma * jnp.eye(16), b)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect),
                               atol=0.05)


def test_mbprox_sync_equals_local_on_one_shard():
    """On a 1-device mesh the 'local' and 'sync' variants are the same
    algorithm (no averaging) — outputs must match."""
    from repro.optim.mbprox import MBProxConfig, make_mbprox_step
    from repro.launch.mesh import make_host_mesh
    loss_quad, _ = _quad_problem()

    def loss_fn(params, micro):
        return loss_quad(params) + 0.0 * micro["x"].sum(), {}

    mesh = make_host_mesh()
    batch = {"x": jnp.zeros((2, 4))}
    params = {"w": jnp.ones(16)}
    outs = {}
    for variant in ("local", "sync"):
        mp = MBProxConfig(gamma=0.2, inner_momentum=0.9, inner_passes=2,
                          dane_correction=False, variant=variant)
        step = make_mbprox_step(loss_fn, mp, mesh, ("data",))
        with compat.set_mesh(mesh):
            p, s, _ = jax.jit(step)(params,
                                    jax.tree.map(jnp.zeros_like, params),
                                    batch, jnp.float32(0.03))
        outs[variant] = p["w"]
    np.testing.assert_allclose(np.asarray(outs["local"]),
                               np.asarray(outs["sync"]), atol=1e-5)


# ----------------------------------------------------------------------------
# compression
# ----------------------------------------------------------------------------

def test_int8_roundtrip_error_feedback():
    k = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(k, (1000,)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (37,))}
    ef = comp.init_ef(tree)
    compressed, ef = comp.quantize_int8(tree, ef)
    deq = comp.dequantize_int8(compressed)
    # block-scaled int8: ~1% relative error per entry
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(deq)):
        err = float(jnp.abs(a - b).max())
        scale = float(jnp.abs(a).max())
        assert err <= scale / 127.0 * 1.01
    # error feedback: residual equals the quantization error
    for r, a, b in zip(jax.tree.leaves(ef.residual), jax.tree.leaves(tree),
                       jax.tree.leaves(deq)):
        np.testing.assert_allclose(np.asarray(r), np.asarray(a - b),
                                   atol=1e-6)
    # wire size ~4x smaller than f32
    wire = comp.compressed_bytes_int8(tree)
    raw = sum(x.size * 4 for x in jax.tree.leaves(tree))
    assert wire < raw / 3.5


def test_error_feedback_unbiased_over_rounds():
    """With EF, the SUM of transmitted (dequantized) values converges to
    the sum of true values — compression error does not accumulate."""
    k = jax.random.PRNGKey(3)
    true = jax.random.normal(k, (512,)) * 0.1
    ef = comp.init_ef({"g": true})
    sent = jnp.zeros_like(true)
    for _ in range(30):
        compressed, ef = comp.quantize_int8({"g": true}, ef)
        sent = sent + comp.dequantize_int8(compressed)["g"]
    np.testing.assert_allclose(np.asarray(sent / 30), np.asarray(true),
                               atol=2e-3)


def test_topk_roundtrip():
    k = jax.random.PRNGKey(1)
    tree = {"w": jax.random.normal(k, (2048,))}
    ef = comp.init_ef(tree)
    compressed, ef = comp.topk_sparsify(tree, ef, frac=0.1)
    dense = comp.topk_densify(compressed)
    nz = int((dense["w"] != 0).sum())
    assert nz == 204  # 10% of 2048
    # kept entries are the largest-magnitude ones
    thresh = float(jnp.sort(jnp.abs(tree["w"]))[-204])
    kept = jnp.abs(dense["w"][dense["w"] != 0])
    assert float(kept.min()) >= thresh - 1e-6
