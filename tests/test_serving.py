"""Serving subsystem: paged-attention kernel vs oracle, block-allocator
invariants under churn, engine outputs vs the legacy generate() path,
prefix-cache on/off token identity, and bucketed batched prefill."""
import os
import random
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.launch.serve import generate
from repro.models import attention, lm
from repro.serving.block_manager import BlockAllocator
from repro.serving.engine import (Request, ServingEngine,
                                  shared_prefix_requests, summarize,
                                  synthetic_requests)

pytestmark = pytest.mark.serving

KEY = jax.random.PRNGKey(0)


def _rand(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape)
            * scale).astype(dtype)


# ----------------------------------------------------------------------------
# Pallas paged-attention kernel vs the jnp oracle
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,hd,bs,M", [
    (2, 4, 4, 32, 8, 3),     # MHA
    (3, 4, 2, 64, 16, 4),    # GQA 2:1
    (1, 8, 1, 128, 8, 2),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_sweep(B, H, KV, hd, bs, M, dtype):
    N = B * M + 1
    q = _rand(1, (B, H, hd), dtype)
    kp = _rand(2, (N, bs, KV, hd), dtype)
    vp = _rand(3, (N, bs, KV, hd), dtype)
    # disjoint tables; ragged context lengths incl. a partial last block
    bt = (1 + jnp.arange(B * M, dtype=jnp.int32)).reshape(B, M)
    cl = jnp.asarray([(i * 7 + 3) % (M * bs) + 1 for i in range(B)],
                     jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl)
    expect = ref.paged_attention_ref(q, kp, vp, bt, cl)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_paged_attention_kernel_empty_slot():
    """ctx_len == 0 lanes (idle decode slots) must return zeros, not NaN."""
    q = _rand(1, (2, 4, 32))
    kp = _rand(2, (5, 8, 2, 32))
    vp = _rand(3, (5, 8, 2, 32))
    bt = jnp.array([[1, 2], [0, 0]], jnp.int32)
    cl = jnp.array([9, 0], jnp.int32)
    out = np.asarray(paged_attention(q, kp, vp, bt, cl))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[1], 0.0)
    expect = np.asarray(ref.paged_attention_ref(q, kp, vp, bt, cl))
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_paged_model_path_matches_dense_decode():
    """paged_decode_attention_block == decode_attention_block on the same
    history (the paged layout must be a pure re-indexing)."""
    cfg = get_config("smollm-135m").reduced()
    B, pos, bs, M = 2, 10, 4, 4
    S_max = M * bs
    params = attention.init_attention(jax.random.fold_in(KEY, 9),
                                      cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim,
                                      jnp.float32)
    x = _rand(4, (B, 1, cfg.d_model), scale=0.3)
    hist_k = _rand(5, (B, S_max, cfg.n_kv_heads, cfg.head_dim))
    hist_v = _rand(6, (B, S_max, cfg.n_kv_heads, cfg.head_dim))
    mask = (jnp.arange(S_max) < pos)[None, :, None, None]
    dense = {"k": hist_k * mask, "v": hist_v * mask}
    out_d, _ = attention.decode_attention_block(params, x, dense,
                                                jnp.int32(pos), cfg)
    # same history scattered into pools through a shuffled block table
    perm = np.array([[3, 1, 4, 2], [7, 5, 8, 6]], np.int32)
    N = 9
    kp = jnp.zeros((N, bs, cfg.n_kv_heads, cfg.head_dim))
    vp = jnp.zeros((N, bs, cfg.n_kv_heads, cfg.head_dim))
    for b in range(B):
        for j in range(M):
            kp = kp.at[perm[b, j]].set(dense["k"][b, j * bs:(j + 1) * bs])
            vp = vp.at[perm[b, j]].set(dense["v"][b, j * bs:(j + 1) * bs])
    out_p, new_cache = attention.paged_decode_attention_block(
        params, x, {"k": kp, "v": vp}, jnp.full((B,), pos, jnp.int32),
        jnp.asarray(perm), cfg)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------------
# Block allocator invariants under random admit/evict churn
# ----------------------------------------------------------------------------

def test_block_allocator_churn():
    rng = random.Random(0)
    alloc = BlockAllocator(64)
    live = {}  # rid -> blocks
    rid = 0
    for _ in range(2000):
        if live and rng.random() < 0.45:
            victim = rng.choice(sorted(live))
            alloc.free(live.pop(victim))
        else:
            n = rng.randint(0, 9)
            got = alloc.alloc(n)
            if got is not None:
                live[rid] = got
                rid += 1
        # invariants: disjoint ownership, no null block, conservation
        owned = [b for bs in live.values() for b in bs]
        assert len(owned) == len(set(owned))
        assert 0 not in owned
        assert alloc.num_free + len(owned) == 63
    # exhaustion returns None without a partial grant
    free_before = alloc.num_free
    assert alloc.alloc(free_before + 1) is None
    assert alloc.num_free == free_before


def test_block_allocator_errors():
    alloc = BlockAllocator(8)
    blocks = alloc.alloc(3)
    alloc.free(blocks)
    with pytest.raises(ValueError):
        alloc.free(blocks)          # double free
    with pytest.raises(ValueError):
        alloc.free([0])             # reserved null block


# ----------------------------------------------------------------------------
# prefill == token-by-token priming
# ----------------------------------------------------------------------------

def test_prefill_matches_stepwise_priming():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)
    logits_pf, _ = lm.prefill(params, cfg, {"tokens": toks})
    state = lm.init_decode_state(cfg, B, max_len=P + 1)
    logits_step = None
    for pos in range(P):
        logits_step, state = lm.decode_step(params, cfg, state,
                                            toks[:, pos], jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits_pf[:, -1]),
                               np.asarray(logits_step),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------------
# engine greedy outputs == generate() (bit-identical token ids)
# ----------------------------------------------------------------------------

def test_engine_matches_generate_exactly():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, P, gen = 4, 8, 12
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    expect = np.asarray(generate(params, cfg, prompts, gen))
    engine = ServingEngine(params, cfg, num_slots=B, block_size=4,
                           max_seq_len=P + gen + 1)
    done = engine.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gen) for i in range(B)])
    assert len(done) == B
    for c in done:
        np.testing.assert_array_equal(c.tokens, expect[c.rid])


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_engine_continuous_batching_churn(arch):
    """More requests than slots, ragged lengths: every request completes,
    every output matches its own single-request generate(), and all blocks
    are returned to the pool."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n, P = 7, 8
    gens = [5, 12, 3, 9, 12, 7, 4]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n, P), 0,
                                 cfg.vocab_size)
    engine = ServingEngine(params, cfg, num_slots=3, block_size=4,
                           max_seq_len=P + max(gens) + 1)
    free0 = engine.allocator.num_free
    done = engine.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gens[i]) for i in range(n)])
    assert len(done) == n
    assert engine.allocator.num_free == free0
    for c in done:
        expect = np.asarray(generate(params, cfg, prompts[c.rid][None],
                                     gens[c.rid]))[0]
        np.testing.assert_array_equal(c.tokens, expect)


def test_engine_eos_and_telemetry():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                 cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompts, 8))[0]
    eos = int(full[3])  # stops at eos's FIRST occurrence (may be < index 3)
    stop = int(np.argmax(full == eos)) + 1
    engine = ServingEngine(params, cfg, num_slots=2, block_size=4,
                           max_seq_len=32)
    done = engine.run([Request(rid=0, prompt=np.asarray(prompts[0]),
                               max_new_tokens=8, eos_id=eos)])
    assert len(done[0].tokens) == stop
    np.testing.assert_array_equal(done[0].tokens, full[:stop])
    from repro.serving.engine import summarize
    stats = summarize(done, engine.wall_time, engine)
    assert stats["generated_tokens"] == stop
    assert stats["tokens_per_s"] > 0
    assert 0 < stats["slot_occupancy"] <= 1
    assert stats["kv_cache_mb"] > 0
    # TTFT covers admission->first token, and timestamps are ordered
    c = done[0]
    assert c.arrival <= c.t_admit <= c.t_first_token <= c.t_done
    # empty run: telemetry degrades gracefully
    empty = summarize(engine.run([]), engine.wall_time, engine)
    assert empty["requests"] == 0 and empty["tokens_per_s"] == 0.0


def test_synthetic_requests_open_loop():
    reqs = synthetic_requests(16, vocab_size=100, prompt_len=8,
                              max_new=(2, 5), rate=100.0, seed=3)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr) and arr[-1] > 0
    assert all(2 <= r.max_new_tokens <= 5 for r in reqs)
    assert all(r.prompt.shape == (8,) and r.prompt.dtype == np.int32
               for r in reqs)


def test_workload_generators_mixed_and_shared_prefix():
    reqs = synthetic_requests(32, vocab_size=100, prompt_len=(4, 24),
                              max_new=(2, 5), seed=1)
    lens = {len(r.prompt) for r in reqs}
    assert all(4 <= n <= 24 for n in lens) and len(lens) > 4
    reqs = shared_prefix_requests(12, vocab_size=100, prefix_len=16,
                                  suffix_len=(2, 6), max_new=(2, 4),
                                  n_prefixes=2, seed=2)
    p0 = reqs[0].prompt[:16]
    p1 = reqs[1].prompt[:16]
    assert not np.array_equal(p0, p1)          # two distinct system prompts
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.prompt[:16], p0 if i % 2 == 0
                                      else p1)
        assert 18 <= len(r.prompt) <= 22


# ----------------------------------------------------------------------------
# length-masked batched prefill (models/lm.py)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_prefill_length_masked(arch):
    """Right-padded mixed-length prefill with `lengths` must reproduce
    each row's unpadded logits and (for recurrent mixers) final states."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lens = [5, 11, 8]
    S = max(lens)
    rows = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0,
                               cfg.vocab_size) for i, n in enumerate(lens)]
    toks = jnp.stack([jnp.pad(r, (0, S - len(r))) for r in rows])
    logits, cache = lm.prefill(params, cfg, {
        "tokens": toks, "lengths": jnp.asarray(lens, jnp.int32)})

    def recurrent_leaves(tree):
        out = []
        for kind, st in zip(cfg.prefix_pattern, tree["prefix"]):
            if kind in ("rwkv", "rec"):
                out.extend(jax.tree.leaves(st))
        for pi, kind in enumerate(cfg.block_pattern):
            if kind in ("rwkv", "rec"):
                out.extend(jax.tree.leaves(tree["blocks"][f"p{pi}"]))
        return out

    batched_states = recurrent_leaves(cache)
    for b, row in enumerate(rows):
        ref_logits, ref_cache = lm.prefill(params, cfg,
                                           {"tokens": row[None]})
        np.testing.assert_allclose(
            np.asarray(logits[b, lens[b] - 1]),
            np.asarray(ref_logits[0, -1]), atol=1e-4, rtol=1e-4)
        for got, want in zip(batched_states, recurrent_leaves(ref_cache)):
            # leaves are (B, ...) or stacked (n_super, B, ...)
            got_b = got[b] if got.shape[0] == len(lens) else got[:, b]
            want_b = want[0] if want.shape[0] == 1 else want[:, 0]
            np.testing.assert_allclose(np.asarray(got_b),
                                       np.asarray(want_b),
                                       atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_prefill_paged_matches_per_sequence_load_prefill(arch):
    """The fused batched path (lm.prefill_paged) must leave the paged
    state identical to the per-sequence oracle (lm.prefill + kv_cache.
    load_prefill) — same KV in every block it owns, same recurrent slot
    state, same last-token logits."""
    from repro.serving import kv_cache
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    bs, num_slots, M = 4, 2, 4
    lens = [7, 10]
    rows = [jax.random.randint(jax.random.fold_in(KEY, 20 + i), (n,), 0,
                               cfg.vocab_size) for i, n in enumerate(lens)]
    tables = np.full((2, M), 0, np.int32)
    tables[0, :2] = [1, 2]
    tables[1, :3] = [3, 4, 5]

    oracle = kv_cache.init_paged_state(cfg, num_slots, 6, bs)
    ref_last = []
    for i, row in enumerate(rows):
        logits, cache = lm.prefill(params, cfg, {"tokens": row[None]})
        oracle = kv_cache.load_prefill(cfg, oracle, cache, jnp.int32(i),
                                       jnp.asarray(tables[i]), bs)
        ref_last.append(np.asarray(logits[0, lens[i] - 1]))

    fused = kv_cache.init_paged_state(cfg, num_slots, 6, bs)
    Ls = max(lens)
    toks = jnp.stack([jnp.pad(r, (0, Ls - len(r))) for r in rows])
    last, fused = lm.prefill_paged(
        params, cfg, fused, toks, jnp.asarray(lens, jnp.int32),
        jnp.zeros(2, jnp.int32), jnp.asarray(tables),
        jnp.arange(2, dtype=jnp.int32))

    np.testing.assert_allclose(np.asarray(last), np.stack(ref_last),
                               atol=1e-4, rtol=1e-4)
    # compare every owned block / slot; block 0 is the pad sink (skip it)
    for got, want in zip(jax.tree.leaves(fused), jax.tree.leaves(oracle)):
        got, want = np.asarray(got), np.asarray(want)
        if got.shape[-4:-2] == (6, bs) or got.shape[:2] == (6, bs):
            np.testing.assert_allclose(got[..., 1:, :, :, :]
                                       if got.ndim == 5 else got[1:],
                                       want[..., 1:, :, :, :]
                                       if want.ndim == 5 else want[1:],
                                       atol=1e-4, rtol=1e-4)
        else:                         # recurrent slot state
            np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------------
# prefix caching: token identity on/off, copy-on-write, churn
# ----------------------------------------------------------------------------

def _engine_outputs(params, cfg, reqs, **kw):
    eng = ServingEngine(params, cfg, **kw)
    done = eng.run(list(reqs))
    assert len(done) == len(reqs)
    return {c.rid: c.tokens for c in done}, eng


def test_prefix_cache_on_off_identical_under_churn():
    """Greedy outputs must be token-identical with the prefix cache on
    vs off and vs generate(), with more requests than slots (admit/evict
    churn) on a shared-prefix workload that hits every sharing path."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = shared_prefix_requests(9, vocab_size=cfg.vocab_size,
                                  prefix_len=20, suffix_len=(1, 9),
                                  max_new=(2, 7), seed=4)
    kw = dict(num_slots=3, block_size=8, max_seq_len=48,
              prefill_max_batch=2)
    on, eng_on = _engine_outputs(params, cfg, reqs, prefix_cache=True, **kw)
    off, eng_off = _engine_outputs(params, cfg, reqs, prefix_cache=False,
                                   **kw)
    assert eng_on.scheduler.cached_prompt_tokens > 0
    assert eng_off.scheduler.cached_prompt_tokens == 0
    for r in reqs:
        exp = np.asarray(generate(params, cfg, np.asarray(r.prompt)[None],
                                  r.max_new_tokens))[0]
        np.testing.assert_array_equal(on[r.rid], exp)
        np.testing.assert_array_equal(off[r.rid], exp)
    # all blocks returned (shared ones may idle in the cached-free pool)
    assert eng_on.allocator.num_free == eng_on.allocator.num_blocks - 1


def test_prefix_cache_copy_on_write_paths():
    """Eager COW (prompt diverges mid-block) and lazy COW (whole prompt
    cached; generation writes the shared block) both fire and stay
    token-identical to generate()."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab_size, 27).astype(np.int32)
    reqs = [Request(rid=0, prompt=base, max_new_tokens=4),
            # same first 22 tokens, diverges inside block 2 -> eager COW
            Request(rid=1, prompt=np.concatenate(
                [base[:22], rng.integers(0, cfg.vocab_size, 5)
                 .astype(np.int32)]), max_new_tokens=5),
            # strict prefix ending mid-block -> fully cached -> lazy COW
            Request(rid=2, prompt=base[:20].copy(), max_new_tokens=6)]
    out, eng = _engine_outputs(params, cfg, reqs, num_slots=1,
                               block_size=8, max_seq_len=64,
                               prefix_cache=True)
    assert eng.runner.block_copies >= 2          # one eager + one lazy
    for r in reqs:
        exp = np.asarray(generate(params, cfg, np.asarray(r.prompt)[None],
                                  r.max_new_tokens))[0]
        np.testing.assert_array_equal(out[r.rid], exp)


def test_prefix_cache_hit_under_tight_pool_backpressures():
    """Admission must charge for matched blocks it revives from the
    cached-free pool: with a pool sized so a cache-hit admission would
    otherwise over-commit the reserved block budget, the request has to
    wait (backpressure), not crash a later infallible claim. Regression
    for the incremental-allocation admission gate."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    pa = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    pc = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=16, num_blocks=7, prefix_cache=True)
    # A completes and parks its 2 prompt blocks in the cached-free pool
    eng.run([Request(rid=0, prompt=pa, max_new_tokens=8)])
    # C (distinct) binds blocks + budget; B (cache hit on A) must wait
    # until C's blocks come back even though num_free looks sufficient
    reqs = [Request(rid=1, prompt=pc, max_new_tokens=8),
            Request(rid=2, prompt=pa.copy(), max_new_tokens=8)]
    done = eng.run(list(reqs))
    assert len(done) == 2
    for c in done:
        exp = np.asarray(generate(params, cfg,
                                  np.asarray(reqs[c.rid - 1].prompt)[None],
                                  8))[0]
        np.testing.assert_array_equal(c.tokens, exp)
    # everything back in the allocatable supply (free or cached-free)
    assert eng.allocator.num_free == eng.allocator.num_blocks - 1


def test_prefix_cache_rejected_for_recurrent_archs():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, prefix_cache=True)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32)
    assert not eng.prefix_cache                  # auto-gated off


# ----------------------------------------------------------------------------
# bucketed batched prefill
# ----------------------------------------------------------------------------

def test_bucketed_prefill_mixed_lengths_matches_generate():
    """Mixed-length traffic: every output matches generate(), and the
    number of distinct prefill jit shapes is bounded by the bucket grid,
    not by the number of distinct prompt lengths."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = synthetic_requests(12, vocab_size=cfg.vocab_size,
                              prompt_len=(3, 40), max_new=(2, 6), seed=6)
    out, eng = _engine_outputs(params, cfg, reqs, num_slots=4,
                               block_size=8, max_seq_len=64,
                               prefill_max_batch=4)
    n_lens = len({len(r.prompt) for r in reqs})
    bound = len(eng.runner.prefill_buckets) * len(eng.runner.width_buckets)
    assert len(eng.runner.prefill_shapes) <= bound
    assert len(eng.runner.prefill_shapes) < n_lens
    assert eng.runner.prefill_dispatches < len(reqs)   # batched admission
    for r in reqs:
        exp = np.asarray(generate(params, cfg, np.asarray(r.prompt)[None],
                                  r.max_new_tokens))[0]
        np.testing.assert_array_equal(out[r.rid], exp)


def test_summarize_reports_prefill_and_prefix_stats():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = shared_prefix_requests(4, vocab_size=cfg.vocab_size,
                                  prefix_len=16, suffix_len=4,
                                  max_new=(2, 3), seed=8)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=8,
                        max_seq_len=32)
    stats = summarize(eng.run(reqs), eng.wall_time, eng)
    pf = stats["prefill"]
    assert pf["prompt_tokens"] == sum(len(r.prompt) for r in reqs)
    assert pf["computed_tokens"] + pf["cached_tokens"] \
        == pf["prompt_tokens"]
    assert pf["cached_tokens"] > 0
    assert pf["shapes"] <= pf["buckets"]
    assert stats["prefix_cache"]["enabled"]
    assert stats["prefix_cache"]["hit_requests"] > 0


# ----------------------------------------------------------------------------
# serving_bench is importable and runs end to end (CI smoke)
# ----------------------------------------------------------------------------

def test_serving_bench_smoke(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        import serving_bench
    finally:
        sys.path.pop(0)
    rec = serving_bench.run_bench([
        "--requests", "3", "--prompt-len", "6", "12", "--max-new", "2", "3",
        "--slots", "2", "--block-size", "4", "--workload", "mixed",
        "--out", str(tmp_path)])
    assert rec["speedup"] > 0
    assert rec["engine"]["requests"] == 3
    assert (tmp_path / "bench_smollm-135m_mixed.json").exists()
