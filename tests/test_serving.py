"""Serving subsystem: paged-attention kernel vs oracle, block-allocator
invariants under churn, and engine outputs vs the legacy generate() path."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.launch.serve import generate
from repro.models import attention, lm
from repro.serving.engine import Request, ServingEngine, synthetic_requests
from repro.serving.kv_cache import BlockAllocator

KEY = jax.random.PRNGKey(0)


def _rand(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape)
            * scale).astype(dtype)


# ----------------------------------------------------------------------------
# Pallas paged-attention kernel vs the jnp oracle
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,hd,bs,M", [
    (2, 4, 4, 32, 8, 3),     # MHA
    (3, 4, 2, 64, 16, 4),    # GQA 2:1
    (1, 8, 1, 128, 8, 2),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_sweep(B, H, KV, hd, bs, M, dtype):
    N = B * M + 1
    q = _rand(1, (B, H, hd), dtype)
    kp = _rand(2, (N, bs, KV, hd), dtype)
    vp = _rand(3, (N, bs, KV, hd), dtype)
    # disjoint tables; ragged context lengths incl. a partial last block
    bt = (1 + jnp.arange(B * M, dtype=jnp.int32)).reshape(B, M)
    cl = jnp.asarray([(i * 7 + 3) % (M * bs) + 1 for i in range(B)],
                     jnp.int32)
    out = paged_attention(q, kp, vp, bt, cl)
    expect = ref.paged_attention_ref(q, kp, vp, bt, cl)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_paged_attention_kernel_empty_slot():
    """ctx_len == 0 lanes (idle decode slots) must return zeros, not NaN."""
    q = _rand(1, (2, 4, 32))
    kp = _rand(2, (5, 8, 2, 32))
    vp = _rand(3, (5, 8, 2, 32))
    bt = jnp.array([[1, 2], [0, 0]], jnp.int32)
    cl = jnp.array([9, 0], jnp.int32)
    out = np.asarray(paged_attention(q, kp, vp, bt, cl))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[1], 0.0)
    expect = np.asarray(ref.paged_attention_ref(q, kp, vp, bt, cl))
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_paged_model_path_matches_dense_decode():
    """paged_decode_attention_block == decode_attention_block on the same
    history (the paged layout must be a pure re-indexing)."""
    cfg = get_config("smollm-135m").reduced()
    B, pos, bs, M = 2, 10, 4, 4
    S_max = M * bs
    params = attention.init_attention(jax.random.fold_in(KEY, 9),
                                      cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim,
                                      jnp.float32)
    x = _rand(4, (B, 1, cfg.d_model), scale=0.3)
    hist_k = _rand(5, (B, S_max, cfg.n_kv_heads, cfg.head_dim))
    hist_v = _rand(6, (B, S_max, cfg.n_kv_heads, cfg.head_dim))
    mask = (jnp.arange(S_max) < pos)[None, :, None, None]
    dense = {"k": hist_k * mask, "v": hist_v * mask}
    out_d, _ = attention.decode_attention_block(params, x, dense,
                                                jnp.int32(pos), cfg)
    # same history scattered into pools through a shuffled block table
    perm = np.array([[3, 1, 4, 2], [7, 5, 8, 6]], np.int32)
    N = 9
    kp = jnp.zeros((N, bs, cfg.n_kv_heads, cfg.head_dim))
    vp = jnp.zeros((N, bs, cfg.n_kv_heads, cfg.head_dim))
    for b in range(B):
        for j in range(M):
            kp = kp.at[perm[b, j]].set(dense["k"][b, j * bs:(j + 1) * bs])
            vp = vp.at[perm[b, j]].set(dense["v"][b, j * bs:(j + 1) * bs])
    out_p, new_cache = attention.paged_decode_attention_block(
        params, x, {"k": kp, "v": vp}, jnp.full((B,), pos, jnp.int32),
        jnp.asarray(perm), cfg)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------------
# Block allocator invariants under random admit/evict churn
# ----------------------------------------------------------------------------

def test_block_allocator_churn():
    rng = random.Random(0)
    alloc = BlockAllocator(64)
    live = {}  # rid -> blocks
    rid = 0
    for _ in range(2000):
        if live and rng.random() < 0.45:
            victim = rng.choice(sorted(live))
            alloc.free(live.pop(victim))
        else:
            n = rng.randint(0, 9)
            got = alloc.alloc(n)
            if got is not None:
                live[rid] = got
                rid += 1
        # invariants: disjoint ownership, no null block, conservation
        owned = [b for bs in live.values() for b in bs]
        assert len(owned) == len(set(owned))
        assert 0 not in owned
        assert alloc.num_free + len(owned) == 63
    # exhaustion returns None without a partial grant
    free_before = alloc.num_free
    assert alloc.alloc(free_before + 1) is None
    assert alloc.num_free == free_before


def test_block_allocator_errors():
    alloc = BlockAllocator(8)
    blocks = alloc.alloc(3)
    alloc.free(blocks)
    with pytest.raises(ValueError):
        alloc.free(blocks)          # double free
    with pytest.raises(ValueError):
        alloc.free([0])             # reserved null block


# ----------------------------------------------------------------------------
# prefill == token-by-token priming
# ----------------------------------------------------------------------------

def test_prefill_matches_stepwise_priming():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)
    logits_pf, _ = lm.prefill(params, cfg, {"tokens": toks})
    state = lm.init_decode_state(cfg, B, max_len=P + 1)
    logits_step = None
    for pos in range(P):
        logits_step, state = lm.decode_step(params, cfg, state,
                                            toks[:, pos], jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits_pf[:, -1]),
                               np.asarray(logits_step),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------------
# engine greedy outputs == generate() (bit-identical token ids)
# ----------------------------------------------------------------------------

def test_engine_matches_generate_exactly():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, P, gen = 4, 8, 12
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    expect = np.asarray(generate(params, cfg, prompts, gen))
    engine = ServingEngine(params, cfg, num_slots=B, block_size=4,
                           max_seq_len=P + gen + 1)
    done = engine.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gen) for i in range(B)])
    assert len(done) == B
    for c in done:
        np.testing.assert_array_equal(c.tokens, expect[c.rid])


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_engine_continuous_batching_churn(arch):
    """More requests than slots, ragged lengths: every request completes,
    every output matches its own single-request generate(), and all blocks
    are returned to the pool."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n, P = 7, 8
    gens = [5, 12, 3, 9, 12, 7, 4]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n, P), 0,
                                 cfg.vocab_size)
    engine = ServingEngine(params, cfg, num_slots=3, block_size=4,
                           max_seq_len=P + max(gens) + 1)
    free0 = engine.allocator.num_free
    done = engine.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gens[i]) for i in range(n)])
    assert len(done) == n
    assert engine.allocator.num_free == free0
    for c in done:
        expect = np.asarray(generate(params, cfg, prompts[c.rid][None],
                                     gens[c.rid]))[0]
        np.testing.assert_array_equal(c.tokens, expect)


def test_engine_eos_and_telemetry():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                 cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompts, 8))[0]
    eos = int(full[3])  # stops at eos's FIRST occurrence (may be < index 3)
    stop = int(np.argmax(full == eos)) + 1
    engine = ServingEngine(params, cfg, num_slots=2, block_size=4,
                           max_seq_len=32)
    done = engine.run([Request(rid=0, prompt=np.asarray(prompts[0]),
                               max_new_tokens=8, eos_id=eos)])
    assert len(done[0].tokens) == stop
    np.testing.assert_array_equal(done[0].tokens, full[:stop])
    from repro.serving.engine import summarize
    stats = summarize(done, engine.wall_time, engine)
    assert stats["generated_tokens"] == stop
    assert stats["tokens_per_s"] > 0
    assert 0 < stats["slot_occupancy"] <= 1
    assert stats["kv_cache_mb"] > 0
    # TTFT covers admission->first token, and timestamps are ordered
    c = done[0]
    assert c.arrival <= c.t_admit <= c.t_first_token <= c.t_done
    # empty run: telemetry degrades gracefully
    empty = summarize(engine.run([]), engine.wall_time, engine)
    assert empty["requests"] == 0 and empty["tokens_per_s"] == 0.0


def test_synthetic_requests_open_loop():
    reqs = synthetic_requests(16, vocab_size=100, prompt_len=8,
                              max_new=(2, 5), rate=100.0, seed=3)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr) and arr[-1] > 0
    assert all(2 <= r.max_new_tokens <= 5 for r in reqs)
    assert all(r.prompt.shape == (8,) and r.prompt.dtype == np.int32
               for r in reqs)
