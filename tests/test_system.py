"""End-to-end behaviour tests for the system."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_reduces_loss_mbprox():
    from repro.launch.train import train
    _, losses = train("smollm-135m", 60, optimizer="mbprox", lr=5e-2,
                      batch_size=8, seq_len=32, log_every=1000)
    assert min(losses) < losses[0] - 0.2, (losses[0], min(losses))


def test_train_reduces_loss_baseline():
    from repro.launch.train import train
    _, losses = train("smollm-135m", 60, optimizer="baseline", lr=2e-2,
                      batch_size=8, seq_len=32, log_every=1000)
    assert min(losses) < losses[0] - 0.3


def test_generate_end_to_end():
    from repro import compat
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.models import lm
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    with compat.set_mesh(make_host_mesh()):
        toks = generate(params, cfg, prompts, 12)
    assert toks.shape == (2, 12)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab_size).all())
    # greedy decode is deterministic
    with compat.set_mesh(make_host_mesh()):
        toks2 = generate(params, cfg, prompts, 12)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_hlo_parser_known_flops():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jnp.zeros((128, 128))
    w = jnp.zeros((128, 128))
    comp = jax.jit(f).lower(x, w).compile()
    r = analyze_hlo(comp.as_text())
    assert r["dot_flops"] == 5 * 2 * 128**3


def test_hlo_parser_grad_remat_flops():
    from repro.launch.hlo_analysis import analyze_hlo

    def g(w, x):
        def body(c, _):
            return jax.checkpoint(lambda c: jnp.tanh(c @ w))(c), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    comp = jax.jit(jax.grad(g)).lower(jnp.zeros((64, 64)),
                                      jnp.zeros((64, 64))).compile()
    r = analyze_hlo(comp.as_text())
    assert r["dot_flops"] == 7 * 2 * 64**3 * 4  # fwd + 2 bwd + remat refwd


_EW_HLO_FIXTURE = """\
HloModule ew_fixture

%fused_softmaxish (p0: f32[8,32]) -> f32[8,32] {
  %p0 = f32[8,32]{1,0} parameter(0)
  %exp = f32[8,32]{1,0} exponential(f32[8,32]{1,0} %p0)
  %two = f32[] constant(2)
  %bt = f32[8,32]{1,0} broadcast(f32[] %two), dimensions={}
  ROOT %mul = f32[8,32]{1,0} multiply(f32[8,32]{1,0} %exp, f32[8,32]{1,0} %bt)
}

%add_red (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (x: f32[8,32], y: f32[8,32], i: s32[8,32]) -> f32[8] {
  %x = f32[8,32]{1,0} parameter(0)
  %y = f32[8,32]{1,0} parameter(1)
  %i = s32[8,32]{1,0} parameter(2)
  %add.1 = f32[8,32]{1,0} add(f32[8,32]{1,0} %x, f32[8,32]{1,0} %y)
  %tanh.1 = f32[8,32]{1,0} tanh(f32[8,32]{1,0} %add.1)
  %iadd = s32[8,32]{1,0} add(s32[8,32]{1,0} %i, s32[8,32]{1,0} %i)
  %conv = f32[8,32]{1,0} convert(s32[8,32]{1,0} %iadd)
  %fus = f32[8,32]{1,0} fusion(f32[8,32]{1,0} %tanh.1), kind=kLoop, calls=%fused_softmaxish
  %zero = f32[] constant(0)
  ROOT %red = f32[8]{0} reduce(f32[8,32]{1,0} %fus, f32[] %zero), dimensions={1}, to_apply=%add_red
}
"""


def test_hlo_parser_elementwise_flops_fixture():
    """Elementwise accounting on a hand-written HLO fixture: float
    add/tanh count 1 FLOP per element, the fusion body's exp+multiply
    count through the call site, reduce counts its input elements, and
    integer adds / converts / constants / broadcasts count nothing."""
    from repro.launch.hlo_analysis import analyze_hlo
    r = analyze_hlo(_EW_HLO_FIXTURE, entry="main")
    n = 8 * 32
    # add + tanh (entry) + exp + multiply (fusion) + reduce input + the
    # reduce body's scalar add (visited once via to_apply)
    assert r["elementwise_flops"] == 4 * n + n + 1
    assert r["dot_flops"] == 0


def test_hlo_parser_elementwise_real_program():
    """Elementwise FLOPs on a real compiled program: softmax over
    (64, 512) must count at least exp + divide + reduce passes, and the
    dot-only accounting is unchanged by the new pass."""
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        return jax.nn.softmax(x @ w, axis=-1)

    comp = jax.jit(f).lower(jnp.zeros((64, 128)),
                            jnp.zeros((128, 512))).compile()
    r = analyze_hlo(comp.as_text())
    assert r["dot_flops"] == 2 * 64 * 128 * 512
    assert r["elementwise_flops"] >= 3 * 64 * 512  # exp, div, max/sum


def test_roofline_elementwise_compute_term():
    """The roofline compute bound must charge elementwise FLOPs to the
    VPU on top of dot FLOPs on the MXU — pinned on the hand-written HLO
    fixture above (4n+n+1 elementwise FLOPs, zero dot FLOPs), where a
    dot-only bound would be exactly zero."""
    from repro.launch.hlo_analysis import (PEAK_FLOPS, VPU_FLOPS,
                                           analyze_hlo, roofline)
    r = analyze_hlo(_EW_HLO_FIXTURE, entry="main")
    ew = 4 * 8 * 32 + 8 * 32 + 1
    assert r["elementwise_flops"] == ew
    roof = roofline(r["dot_flops"], hbm_bytes=0.0, coll_stats={},
                    n_chips=1, model_flops=0.0,
                    ew_flops=r["elementwise_flops"])
    assert roof.compute_s == ew / VPU_FLOPS
    assert roof.ew_flops == ew
    assert roof.bottleneck == "compute"
    # both units are charged serially when dot FLOPs are present
    roof2 = roofline(1e9, hbm_bytes=0.0, coll_stats={}, n_chips=1,
                     model_flops=0.0, ew_flops=ew)
    assert roof2.compute_s == 1e9 / PEAK_FLOPS + ew / VPU_FLOPS
    # omitting ew_flops reproduces the old dot-only bound
    assert roofline(1e9, 0.0, {}, 1, 0.0).compute_s == 1e9 / PEAK_FLOPS


def test_sanitize_spec():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import sanitize_spec

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    spec = sanitize_spec(P("data", "model"), (32, 48), FakeMesh)
    assert tuple(spec) == ("data", "model")
    spec = sanitize_spec(P("data", "model"), (8, 48), FakeMesh)
    assert tuple(spec) == (None, "model")
    spec = sanitize_spec(P(("data", "model"), None), (256, 8), FakeMesh)
    assert tuple(spec) == (("data", "model"), None)
    spec = sanitize_spec(P(("data", "model"), None), (100, 8), FakeMesh)
    assert tuple(spec) == (None, None)


def test_cost_model_components():
    from repro.configs import SHAPES, get_config
    from repro.launch.cost_model import hbm_bytes
    cfg = get_config("codeqwen1.5-7b")
    train = hbm_bytes(cfg, SHAPES["train_4k"], 256)
    dec = hbm_bytes(cfg, SHAPES["decode_32k"], 256)
    assert train["total"] > 0 and dec["total"] > 0
    # decode is kv-cache dominated for a 32k cache
    assert dec["kv_cache"] > dec["weights"]
    # flash kernels remove the attention-scores term
    train_flash = hbm_bytes(cfg, SHAPES["train_4k"], 256, flash=True)
    assert "attention_scores" not in train_flash
    assert train_flash["total"] < train["total"]


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """The dry-run entry point works end-to-end (512 fake devices in a
    fresh process; lowers + compiles + analyzes one real cell)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-135m", "--shape", "train_4k", "--mesh", "single",
         "--out", str(tmp_path), "--force"],
        capture_output=True, text=True, env=env, cwd="/root/repo",
        timeout=540)
    assert "1 ok, 0 skipped, 0 errors" in res.stdout, res.stdout[-2000:]
    import json
    rec = json.load(open(tmp_path / (
        "smollm-135m__train_4k__single__mbprox.json")))
    assert rec["status"] == "ok"
    assert rec["memory"]["fits_16gb"]
    assert rec["roofline"]["flops"] > 0
    assert rec["collectives"]
