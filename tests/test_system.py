"""End-to-end behaviour tests for the system."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_reduces_loss_mbprox():
    from repro.launch.train import train
    _, losses = train("smollm-135m", 60, optimizer="mbprox", lr=5e-2,
                      batch_size=8, seq_len=32, log_every=1000)
    assert min(losses) < losses[0] - 0.2, (losses[0], min(losses))


def test_train_reduces_loss_baseline():
    from repro.launch.train import train
    _, losses = train("smollm-135m", 60, optimizer="baseline", lr=2e-2,
                      batch_size=8, seq_len=32, log_every=1000)
    assert min(losses) < losses[0] - 0.3


def test_generate_end_to_end():
    from repro import compat
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.models import lm
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    with compat.set_mesh(make_host_mesh()):
        toks = generate(params, cfg, prompts, 12)
    assert toks.shape == (2, 12)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab_size).all())
    # greedy decode is deterministic
    with compat.set_mesh(make_host_mesh()):
        toks2 = generate(params, cfg, prompts, 12)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_hlo_parser_known_flops():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jnp.zeros((128, 128))
    w = jnp.zeros((128, 128))
    comp = jax.jit(f).lower(x, w).compile()
    r = analyze_hlo(comp.as_text())
    assert r["dot_flops"] == 5 * 2 * 128**3


def test_hlo_parser_grad_remat_flops():
    from repro.launch.hlo_analysis import analyze_hlo

    def g(w, x):
        def body(c, _):
            return jax.checkpoint(lambda c: jnp.tanh(c @ w))(c), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    comp = jax.jit(jax.grad(g)).lower(jnp.zeros((64, 64)),
                                      jnp.zeros((64, 64))).compile()
    r = analyze_hlo(comp.as_text())
    assert r["dot_flops"] == 7 * 2 * 64**3 * 4  # fwd + 2 bwd + remat refwd


def test_sanitize_spec():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import sanitize_spec

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    spec = sanitize_spec(P("data", "model"), (32, 48), FakeMesh)
    assert tuple(spec) == ("data", "model")
    spec = sanitize_spec(P("data", "model"), (8, 48), FakeMesh)
    assert tuple(spec) == (None, "model")
    spec = sanitize_spec(P(("data", "model"), None), (256, 8), FakeMesh)
    assert tuple(spec) == (("data", "model"), None)
    spec = sanitize_spec(P(("data", "model"), None), (100, 8), FakeMesh)
    assert tuple(spec) == (None, None)


def test_cost_model_components():
    from repro.configs import SHAPES, get_config
    from repro.launch.cost_model import hbm_bytes
    cfg = get_config("codeqwen1.5-7b")
    train = hbm_bytes(cfg, SHAPES["train_4k"], 256)
    dec = hbm_bytes(cfg, SHAPES["decode_32k"], 256)
    assert train["total"] > 0 and dec["total"] > 0
    # decode is kv-cache dominated for a 32k cache
    assert dec["kv_cache"] > dec["weights"]
    # flash kernels remove the attention-scores term
    train_flash = hbm_bytes(cfg, SHAPES["train_4k"], 256, flash=True)
    assert "attention_scores" not in train_flash
    assert train_flash["total"] < train["total"]


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """The dry-run entry point works end-to-end (512 fake devices in a
    fresh process; lowers + compiles + analyzes one real cell)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-135m", "--shape", "train_4k", "--mesh", "single",
         "--out", str(tmp_path), "--force"],
        capture_output=True, text=True, env=env, cwd="/root/repo",
        timeout=540)
    assert "1 ok, 0 skipped, 0 errors" in res.stdout, res.stdout[-2000:]
    import json
    rec = json.load(open(tmp_path / (
        "smollm-135m__train_4k__single__mbprox.json")))
    assert rec["status"] == "ok"
    assert rec["memory"]["fits_16gb"]
    assert rec["roofline"]["flops"] > 0
    assert rec["collectives"]
