"""Data pipeline: determinism, sharding, prefetch, restart-reproducibility."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher, ShardedBatcher
from repro.data.synthetic import LeastSquaresStream, TokenStream


def _sample_fn(key, n):
    return jax.random.normal(key, (n, 4))


def test_batcher_deterministic():
    b1 = ShardedBatcher(_sample_fn, 8, seed=3)
    b2 = ShardedBatcher(_sample_fn, 8, seed=3)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(np.asarray(b1.batch_at(step)),
                                      np.asarray(b2.batch_at(step)))
    # different steps differ
    assert not np.array_equal(np.asarray(b1.batch_at(0)),
                              np.asarray(b1.batch_at(1)))


def test_batcher_shards_disjoint():
    shards = [ShardedBatcher(_sample_fn, 8, n_shards=4, shard_index=i,
                             seed=0) for i in range(4)]
    batches = [np.asarray(s.batch_at(2)) for s in shards]
    assert all(b.shape == (2, 4) for b in batches)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_restart_reproducibility():
    """A 'restarted' consumer resumes at step k with identical data —
    the FT property the checkpointing design relies on."""
    b = ShardedBatcher(_sample_fn, 4, seed=9)
    full = [np.asarray(x) for x in itertools.islice(iter(b), 6)]
    resumed = [np.asarray(b.batch_at(s)) for s in range(3, 6)]
    for a, c in zip(full[3:], resumed):
        np.testing.assert_array_equal(a, c)


def test_prefetcher_order_and_close():
    b = ShardedBatcher(_sample_fn, 4, seed=1)
    direct = [np.asarray(x) for x in itertools.islice(iter(b), 5)]
    pf = Prefetcher(itertools.islice(iter(b), 5), depth=2)
    fetched = [np.asarray(x) for x in pf]
    assert len(fetched) == 5
    for a, c in zip(direct, fetched):
        np.testing.assert_array_equal(a, c)
    pf.close()


def test_streams_are_reproducible():
    s = LeastSquaresStream(dim=8, seed=0)
    X1, y1 = s.sample(jax.random.PRNGKey(5), 16)
    X2, y2 = s.sample(jax.random.PRNGKey(5), 16)
    np.testing.assert_array_equal(np.asarray(X1), np.asarray(X2))
    t = TokenStream(vocab_size=64, seq_len=8, seed=0)
    a1 = t.batch(jax.random.PRNGKey(7), 4)
    a2 = t.batch(jax.random.PRNGKey(7), 4)
    np.testing.assert_array_equal(np.asarray(a1[0]), np.asarray(a2[0]))


def test_compressed_pmean_single_device():
    from repro.distributed.collectives import (compressed_pmean, pmean_tree,
                                               wire_bytes)
    from repro.optim import compression as comp
    trees = {"g": jax.random.normal(jax.random.PRNGKey(0), (2, 512,))}
    ef = comp.init_ef({"g": trees["g"][0]})

    def f(t, e):
        return compressed_pmean(t, e, "i")

    avg, ef2 = jax.vmap(f, axis_name="i", in_axes=(0, None))(trees, ef)
    expect = np.asarray(trees["g"]).mean(0)
    np.testing.assert_allclose(np.asarray(avg["g"][0]), expect, atol=2e-2)
    tree = {"g": trees["g"][0]}
    assert wire_bytes(tree, compressed=True) < wire_bytes(tree) / 3.5
