"""Priority classes and preempt-resume: the bit-identity property
(a preempted-then-resumed request's output is byte-equal to an
uninterrupted run AND to generate(), with allocator refcounts/pools
restored exactly — hypothesis-driven over request mixes and preempt
points), deterministic mid-decode preempt coverage, and the admission
ordering units: class ranking, FCFS within a class, and the aging bound
that keeps low-priority requests starvation-free against a stream of
fresh high-priority arrivals."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingParams

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def data(*a, **k):
            return None


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


@pytest.fixture(scope="module")
def engine(smollm):
    params, cfg = smollm
    return ServingEngine(params, cfg, num_slots=2, block_size=4,
                         max_seq_len=48, prefill_max_batch=2)


_ORACLE = {}


def _oracle(params, cfg, prompt, gen):
    key = (tuple(int(t) for t in prompt), gen)
    if key not in _ORACLE:
        _ORACLE[key] = np.asarray(
            generate(params, cfg, np.asarray(prompt)[None], gen))[0]
    return _ORACLE[key]


def _reqs(rng, n, plens, gens, prios, vocab):
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, plens[i]).astype(np.int32),
                    max_new_tokens=gens[i], arrival=0.0,
                    priority=prios[i]) for i in range(n)]


def _run_with_preempts(eng, reqs, preempt_at):
    """Drive the engine manually, firing scheduler.preempt() after the
    given step counts (mid-decode: preempt() only ever evicts a slot
    that is past prefill)."""
    eng.reset_prefix_cache()
    baseline_free = eng.allocator.num_free
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.has_work:
        eng.step()
        steps += 1
        if steps in preempt_at:
            eng.scheduler.preempt()
        assert steps < 10_000
    done = eng.scheduler.completions
    eng.scheduler.completions = []
    return done, baseline_free


def _assert_clean(eng, baseline_free):
    """Preempt-resume leaves no residue: every refcount dropped, the
    reserved-budget ledger balanced, no orphaned resume state, and the
    free + cached-free pools together hold every block again."""
    assert eng.scheduler._resume_state == {}
    assert eng.scheduler._reserved_budget == 0
    assert eng.allocator._ref == {}
    assert eng.allocator.num_free == baseline_free
    assert eng.scheduler.preemptions == eng.scheduler.resumes


def test_preempt_resume_bit_identical_deterministic(engine, smollm):
    """Forced preemptions at fixed mid-decode steps: outputs must equal
    generate() exactly, and the preempt path must actually run."""
    params, cfg = smollm
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, 3, [8, 6, 10], [8, 6, 7], [0, 1, 0],
                 cfg.vocab_size)
    engine.scheduler.reset_stats()
    done, base_free = _run_with_preempts(engine, reqs, {2, 4, 7})
    assert engine.scheduler.preemptions >= 1
    assert engine.scheduler.resumes == engine.scheduler.preemptions
    by_rid = {c.rid: c.tokens for c in done}
    assert set(by_rid) == {0, 1, 2}
    for r in reqs:
        want = _oracle(params, cfg, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(by_rid[r.rid], want)
    _assert_clean(engine, base_free)


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_preempt_resume_property(engine, smollm, data):
    """Any request mix, any preempt points: outputs bit-identical to
    generate() and allocator pools restored exactly."""
    params, cfg = smollm
    n = data.draw(st.integers(2, 4))
    seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    plens = [data.draw(st.integers(5, 12)) for _ in range(n)]
    gens = [data.draw(st.integers(3, 8)) for _ in range(n)]
    prios = [data.draw(st.integers(0, 1)) for _ in range(n)]
    preempt_at = {data.draw(st.integers(1, 24))
                  for _ in range(data.draw(st.integers(1, 3)))}
    reqs = _reqs(rng, n, plens, gens, prios, cfg.vocab_size)
    engine.scheduler.reset_stats()
    done, base_free = _run_with_preempts(engine, reqs, preempt_at)
    by_rid = {c.rid: c.tokens for c in done}
    assert set(by_rid) == set(range(n))
    for r in reqs:
        want = _oracle(params, cfg, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(by_rid[r.rid], want)
    _assert_clean(engine, base_free)


def test_preempt_returns_none_on_empty_engine(engine):
    assert not engine.has_work
    assert engine.scheduler.preempt() is None


# ----------------------------------------------------------------------------
# admission-order units (fake clock, no dispatches)
# ----------------------------------------------------------------------------

def _submit_at(sched, clock, t, rid, priority):
    clock[0] = t
    req = Request(rid=rid, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=2, priority=priority,
                  sampling=SamplingParams(max_new_tokens=2))
    sched.submit(req)
    return req


def test_priority_ordering_aging_and_fcfs(engine):
    sched = engine.scheduler
    orig_now, orig_aging = sched._now, sched.priority_aging_s
    clock = [0.0]
    sched._now = lambda: clock[0]
    sched.priority_aging_s = 2.0
    try:
        low = _submit_at(sched, clock, 0.0, 900, priority=0)
        low2 = _submit_at(sched, clock, 0.05, 901, priority=0)
        high = _submit_at(sched, clock, 0.1, 902, priority=1)
        # class ranking: the later high-priority request jumps the queue
        assert [r.rid for r in sched._admission_order()] == [902, 900, 901]
        # the aging bound: a request that waited priority_aging_s * gap
        # seconds outranks a FRESH arrival `gap` classes above it (a
        # high request that has ALSO waited keeps its head start — aging
        # is starvation-freedom, not inversion)
        fresh = _submit_at(sched, clock, 2.5, 903, priority=1)
        assert sched._eff_priority(low, 2.5) > sched._eff_priority(fresh,
                                                                   2.5)
        order = [r.rid for r in sched._admission_order()]
        assert order.index(900) < order.index(903)
        assert order[0] == 902                    # waited high stays top
        # ...but not before the bound: at half of it the class wins
        assert sched._eff_priority(low, 0.9) < 1.0
        # FCFS within a class survives aging (equal classes age equally)
        clock[0] = 50.0
        order = [r.rid for r in sched._admission_order()]
        assert order.index(900) < order.index(901)
    finally:
        sched.take_queued()
        sched._now, sched.priority_aging_s = orig_now, orig_aging


def test_aging_disabled_pins_static_classes(engine):
    sched = engine.scheduler
    orig_now, orig_aging = sched._now, sched.priority_aging_s
    clock = [0.0]
    sched._now = lambda: clock[0]
    sched.priority_aging_s = 0.0
    try:
        low = _submit_at(sched, clock, 0.0, 910, priority=0)
        clock[0] = 1000.0
        high = _submit_at(sched, clock, 1000.0, 911, priority=1)
        # no aging: an arbitrarily old low-priority request never
        # outranks a higher class (strict-priority mode)
        assert [r.rid for r in sched._admission_order()] == [911, 910]
        assert sched._eff_priority(low, 1e9) == 0.0
    finally:
        sched.take_queued()
        sched._now, sched.priority_aging_s = orig_now, orig_aging


def test_starvation_freedom_under_high_priority_stream(engine, smollm):
    """Integration: a low-priority request submitted into a continuous
    stream of high-priority work still completes (aging lifts it past
    fresh arrivals instead of letting them queue-jump forever)."""
    params, cfg = smollm
    rng = np.random.default_rng(3)
    low = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=4,
        arrival=0.0, priority=0)
    highs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=4,
        arrival=0.0, priority=3) for i in range(1, 7)]
    old_aging = engine.scheduler.priority_aging_s
    engine.scheduler.priority_aging_s = 0.01   # age fast: bound the test
    try:
        engine.reset_prefix_cache()
        engine.submit(low)
        for h in highs[:3]:
            engine.submit(h)
        steps = 0
        done = []
        while engine.has_work:
            engine.step()
            steps += 1
            if steps <= 3 and steps < len(highs):
                engine.submit(highs[2 + steps])   # keep pressure coming
            done += [c.rid for c in engine.scheduler.completions]
            engine.scheduler.completions = []
            assert steps < 5_000
        assert 0 in done
        want = _oracle(params, cfg, low.prompt, low.max_new_tokens)
    finally:
        engine.scheduler.priority_aging_s = old_aging
