"""Speculative decoding: multi-token verify vs sequential decode,
n-gram proposer, accept/rollback invariants (blocks + recurrent state),
engine greedy bit-identity under speculation, and the verify-shape
compile bound."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.block_manager import NULL_BLOCK, BlockAllocator
from repro.serving.bucketing import (chain_buckets, next_pow2, pick_bucket,
                                     pow2_buckets, width_buckets)
from repro.serving.draft import NGramProposer, make_proposer
from repro.serving.engine import (Request, ServingEngine,
                                  repetitive_requests,
                                  shared_prefix_requests, summarize)
from repro.serving.scheduler import Scheduler
from repro.serving import kv_cache

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def lists(*a, **k):
            return None

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------------
# bucketing helpers (shared grid definitions)
# ----------------------------------------------------------------------------

def test_bucketing_helpers():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 8, 9)] == [1, 1, 2, 4, 8, 16]
    assert pow2_buckets(64, start=16) == [16, 32, 64]
    assert pow2_buckets(60, start=16) == [16, 32, 64]
    assert pow2_buckets(5) == [1, 2, 4, 8]
    assert width_buckets(4) == [1, 2, 4]
    assert width_buckets(6) == [1, 2, 4, 6]
    assert pick_bucket(3, [2, 4, 8]) == 4
    assert pick_bucket(9, [2, 4, 8]) == 8   # clamped to the last bucket
    assert chain_buckets(4) == [2, 4, 5]    # tops out at speculate+1
    assert chain_buckets(1) == [2]
    assert chain_buckets(0) == []


# ----------------------------------------------------------------------------
# n-gram (prompt-lookup) proposer
# ----------------------------------------------------------------------------

def test_ngram_proposer_basic():
    p = NGramProposer(max_ngram=3)
    # history ends in the 2-gram (1, 2) seen earlier -> propose what
    # followed its most recent earlier occurrence
    assert p.propose([1, 2, 3, 4, 1, 2], 2) == [3, 4]
    assert p.propose([1, 2, 3, 4, 1, 2], 4) == [3, 4, 1, 2]
    # no recurring suffix -> nothing proposed
    assert p.propose([1, 2, 3, 4, 5], 4) == []
    assert p.propose([7], 4) == []
    assert p.propose([1, 2, 1, 2], 0) == []


def test_ngram_proposer_prefers_longest_and_most_recent():
    p = NGramProposer(max_ngram=3)
    # suffix (5, 1, 2): full 3-gram match at index 0 beats the shorter
    # 2-gram (1, 2) match later in the stream
    hist = [5, 1, 2, 9, 1, 2, 8, 5, 1, 2]
    assert p.propose(hist, 1) == [9]
    # only 1-gram matches: most recent earlier occurrence of 3 wins
    assert p.propose([3, 7, 3, 8, 4, 3], 1) == [8]


def test_make_proposer():
    assert isinstance(make_proposer("ngram", ngram=4), NGramProposer)
    with pytest.raises(ValueError):
        make_proposer("draft-model")


# ----------------------------------------------------------------------------
# lm.decode_verify_paged == sequential decode_step_paged; commit rollback
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_decode_verify_matches_sequential_decode(arch):
    """Per-position verify logits must equal feeding the chain through
    decode_step_paged one token at a time, and committing a partial
    accept must continue decoding bit-identically to a replay of only
    the accepted prefix (recurrent state rollback)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    bs, M, num_slots = 4, 6, 2
    lens = [7, 10]
    rows = [jax.random.randint(jax.random.fold_in(KEY, 30 + i), (n,), 0,
                               cfg.vocab_size) for i, n in enumerate(lens)]
    tables = np.zeros((2, M), np.int32)
    tables[0, :3] = [1, 2, 3]
    tables[1, :4] = [4, 5, 6, 7]
    state = kv_cache.init_paged_state(cfg, num_slots, 9, bs)
    Ls = max(lens)
    toks = jnp.stack([jnp.pad(r, (0, Ls - len(r))) for r in rows])
    _, state = lm.prefill_paged(params, cfg, state, toks,
                                jnp.asarray(lens, jnp.int32),
                                jnp.zeros(2, jnp.int32),
                                jnp.asarray(tables),
                                jnp.arange(2, dtype=jnp.int32))

    rng = np.random.default_rng(0)
    chains = rng.integers(0, cfg.vocab_size, (2, 4)).astype(np.int32)
    counts = np.array([4, 3], np.int32)
    ref_logits = {0: [], 1: []}
    seq_state = state
    for t in range(4):
        lg, seq_state = lm.decode_step_paged(
            params, cfg, seq_state, jnp.asarray(chains[:, t]),
            jnp.asarray([lens[0] + t, lens[1] + t], jnp.int32),
            jnp.asarray(tables))
        ref_logits[0].append(np.asarray(lg[0]))
        if t < 3:
            ref_logits[1].append(np.asarray(lg[1]))

    logits, vstate, snaps = lm.decode_verify_paged(
        params, cfg, state, jnp.asarray(chains),
        jnp.asarray(lens, jnp.int32), jnp.asarray(counts),
        jnp.asarray(tables))
    for b in range(2):
        for t in range(int(counts[b])):
            np.testing.assert_allclose(np.asarray(logits[b, t]),
                                       ref_logits[b][t],
                                       atol=2e-4, rtol=2e-4)

    # commit lane 0 at 3 consumed tokens, lane 1 at 1; continuing must
    # match a replay that consumed exactly those prefixes
    cstate = lm.commit_decode_state(cfg, vstate, snaps,
                                    jnp.asarray([3, 1], jnp.int32))
    rs = state
    for t in range(3):
        _, rs = lm.decode_step_paged(
            params, cfg, rs, jnp.asarray([chains[0, t], chains[1, 0]]),
            jnp.asarray([lens[0] + t, lens[1]], jnp.int32),
            jnp.asarray(tables))
    rs1 = state
    _, rs1 = lm.decode_step_paged(
        params, cfg, rs1, jnp.asarray([chains[0, 0], chains[1, 0]]),
        jnp.asarray([lens[0], lens[1]], jnp.int32), jnp.asarray(tables))
    nxt = jnp.asarray([11, 12], jnp.int32)
    pos = jnp.asarray([lens[0] + 3, lens[1] + 1], jnp.int32)
    lg_commit, _ = lm.decode_step_paged(params, cfg, cstate, nxt, pos,
                                        jnp.asarray(tables))
    lg_ref0, _ = lm.decode_step_paged(params, cfg, rs, nxt, pos,
                                      jnp.asarray(tables))
    lg_ref1, _ = lm.decode_step_paged(params, cfg, rs1, nxt, pos,
                                      jnp.asarray(tables))
    np.testing.assert_allclose(np.asarray(lg_commit[0]),
                               np.asarray(lg_ref0[0]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(lg_commit[1]),
                               np.asarray(lg_ref1[1]),
                               atol=2e-4, rtol=2e-4)


# ----------------------------------------------------------------------------
# engine: greedy bit-identity under speculation (accept AND reject paths)
# ----------------------------------------------------------------------------

def _expect(params, cfg, req):
    return np.asarray(generate(params, cfg, np.asarray(req.prompt)[None],
                               req.max_new_tokens))[0]


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_engine_speculative_identical_to_generate(arch):
    """n-gram speculation on a repetitive workload: every output must be
    token-identical to generate(), blocks fully returned, and at least
    one draft accepted (the workload is built for lookup hits)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = repetitive_requests(6, vocab_size=cfg.vocab_size, period=5,
                               prompt_len=24, max_new=(10, 20), seed=3)
    eng = ServingEngine(params, cfg, num_slots=3, block_size=4,
                        max_seq_len=64, speculate=4)
    free0 = eng.allocator.num_free
    done = eng.run(list(reqs))
    assert len(done) == len(reqs)
    assert eng.allocator.num_free == free0
    for c in done:
        np.testing.assert_array_equal(c.tokens,
                                      _expect(params, cfg, reqs[c.rid]))
    stats = summarize(done, eng.wall_time, eng)
    sp = stats["speculation"]
    assert sp["proposed_tokens"] > 0 and sp["accepted_tokens"] > 0
    assert 0 < sp["acceptance_rate"] <= 1
    assert sp["verify_dispatches"] > 0
    assert sp["tokens_per_dispatch"] > 0


class _ScriptedProposer:
    """Test proposer that knows each request's true greedy continuation
    and proposes it verbatim (oracle: every draft accepted) or off by
    one (adversarial: every draft rejected)."""

    def __init__(self, scripts, vocab_size, adversarial):
        self.scripts = scripts        # [(prompt list, expected out list)]
        self.vocab_size = vocab_size
        self.adversarial = adversarial

    def propose(self, history, k):
        hist = list(history)
        for prompt, out in self.scripts:
            full = prompt + out
            if (len(prompt) < len(hist) <= len(full)
                    and hist == full[:len(hist)]):
                nxt = full[len(hist):len(hist) + k]
                if self.adversarial:
                    return [(t + 1) % self.vocab_size for t in nxt]
                return nxt
        return []


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
@pytest.mark.parametrize("adversarial", [False, True])
def test_engine_forced_accept_and_reject_paths(arch, adversarial):
    """Oracle drafts (all accepted) and adversarial drafts (all
    rejected — every verify dispatch rolls back) must BOTH leave output
    bit-identical to generate(): full-rollback covers the recurrent
    state-restore satellite end to end."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 9), 0,
                                 cfg.vocab_size)
    gens = [12, 7, 10, 5]
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]),
                    max_new_tokens=gens[i]) for i in range(4)]
    scripts = [([int(t) for t in r.prompt],
                [int(t) for t in _expect(params, cfg, r)]) for r in reqs]
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32, speculate=4)
    prop = _ScriptedProposer(scripts, cfg.vocab_size, adversarial)
    eng.scheduler._proposers = [prop] * eng.num_slots
    free0 = eng.allocator.num_free
    done = eng.run(list(reqs))
    assert len(done) == 4
    assert eng.allocator.num_free == free0
    for c in done:
        np.testing.assert_array_equal(c.tokens, scripts[c.rid][1])
    sched = eng.scheduler
    assert sched.proposed_tokens > 0
    if adversarial:
        assert sched.accepted_tokens == 0          # pure rollback
    else:
        assert sched.accepted_tokens == sched.proposed_tokens


def test_engine_speculative_with_prefix_cache():
    """Speculation composes with prefix caching (shared-prefix
    workload): identity holds, cache hits happen, pools restore."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = shared_prefix_requests(8, vocab_size=cfg.vocab_size,
                                  prefix_len=20, suffix_len=(1, 9),
                                  max_new=(6, 12), seed=4)
    eng = ServingEngine(params, cfg, num_slots=3, block_size=8,
                        max_seq_len=48, prefix_cache=True, speculate=4)
    done = eng.run(list(reqs))
    assert len(done) == len(reqs)
    assert eng.scheduler.cached_prompt_tokens > 0
    for c in done:
        np.testing.assert_array_equal(c.tokens,
                                      _expect(params, cfg, reqs[c.rid]))
    assert eng.allocator.num_free == eng.allocator.num_blocks - 1


def test_engine_speculative_eos_mid_chain():
    """An eos inside an accepted draft run must cut the output at the
    first eos, exactly like unspeculated decoding."""
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full = np.asarray(generate(params, cfg, prompt, 10))[0]
    eos = int(full[4])
    stop = int(np.argmax(full == eos)) + 1
    req = Request(rid=0, prompt=np.asarray(prompt[0]), max_new_tokens=10,
                  eos_id=eos)
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32, speculate=4)
    script = [([int(t) for t in prompt[0]], [int(t) for t in full])]
    eng.scheduler._proposers = [_ScriptedProposer(script, cfg.vocab_size,
                                                  False)] * 2
    done = eng.run([req])
    assert len(done[0].tokens) == stop
    np.testing.assert_array_equal(done[0].tokens, full[:stop])


def test_engine_verify_shapes_bounded_and_flags():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # speculation + temperature used to hard-error (greedy-only); it is
    # now a legal combination (distribution-preserving accept/reject),
    # and the engine-wide temperature knob survives as a deprecated shim
    with pytest.warns(DeprecationWarning):
        ServingEngine(params, cfg, num_slots=2, block_size=4,
                      max_seq_len=32, speculate=4, temperature=0.7)
    reqs = repetitive_requests(8, vocab_size=cfg.vocab_size, period=4,
                               prompt_len=(12, 30), max_new=(4, 18),
                               seed=5)
    eng = ServingEngine(params, cfg, num_slots=4, block_size=4,
                        max_seq_len=64, speculate=5)
    done = eng.run(list(reqs))
    assert len(done) == len(reqs)
    # every verify dispatch shape sits on the bucket grid, so compiles
    # are bounded by the grid — not by the per-step draft lengths; the
    # grid tops out at exactly speculate+1 (a full draft never pads)
    assert eng.runner.verify_buckets == chain_buckets(5) == [2, 4, 6]
    assert eng.runner.verify_shapes <= set(eng.runner.verify_buckets)
    for c in done:
        np.testing.assert_array_equal(c.tokens,
                                      _expect(params, cfg, reqs[c.rid]))


# ----------------------------------------------------------------------------
# accept/rollback block accounting (scheduler + allocator, no device)
# ----------------------------------------------------------------------------

class _FakeRunner:
    """Host-only ModelRunner stand-in: the scheduler's block accounting
    never needs the device."""

    prefill_max_batch = 4
    max_logprobs = 8
    prefill_chunk = 0         # chunked admission off; prompts fit the grid

    def __init__(self, speculate=8):
        self.prefill_buckets = pow2_buckets(64, start=8)
        self.verify_buckets = chain_buckets(speculate)   # same grid as
        # the real runner — test/prod bucket drift is what bucketing.py
        # exists to prevent

    def suffix_bucket(self, n):
        return pick_bucket(n, self.prefill_buckets)

    def chain_bucket(self, n):
        return pick_bucket(n, self.verify_buckets)

    def prefill(self, rows):
        return (np.full(len(rows), 1, np.int32),
                np.zeros(len(rows), np.float32), None)

    def verify(self, tokens, positions, counts):
        # rejects everything: the emitted correction disagrees with
        # every draft and zero drafts are accepted
        return (np.full(tokens.shape, -1, np.int32),
                np.zeros(tokens.shape[0], np.int32),
                np.zeros(tokens.shape, np.float32), None)

    def commit(self, idx):
        pass

    def copy_block(self, src, dst):
        pass

    def write_table(self, slot, row):
        pass

    def clear_table(self, slot):
        pass

    def set_sampling(self, slot, sp):
        pass

    def clear_sampling(self, slot):
        pass


def _alloc_snapshot(alloc):
    return (alloc.num_free, alloc.num_cached, dict(alloc._ref))


def _make_sched(num_blocks=72, bs=4, num_slots=2, speculate=8):
    alloc = BlockAllocator(num_blocks, block_size=bs)
    runner = _FakeRunner(speculate=speculate)
    sched = Scheduler(alloc, runner, num_slots=num_slots, block_size=bs,
                      max_blocks_per_seq=-(-64 // bs), max_seq_len=64,
                      prefix_cache=False, now_fn=lambda: 0.0,
                      speculate=speculate)
    return alloc, sched


@settings(max_examples=60, deadline=None)
@given(plen=st.integers(1, 20), max_new=st.integers(2, 40),
       consumed=st.integers(0, 10), k=st.integers(1, 8),
       bs=st.integers(2, 5))
def test_rejected_draft_frees_exactly_reserved_blocks(plen, max_new,
                                                      consumed, k, bs):
    """Property (satellite): claiming the blocks a k-token draft chain
    would write and then rolling the chain back entirely must return
    the allocator (refcounts, free list, LRU pool) and the slot's
    budget to their exact pre-draft state, from any reachable decode
    position."""
    if plen + max_new > 64:
        max_new = 64 - plen
        if max_new < 2:
            return
    consumed = min(consumed, max_new - 1)
    alloc, sched = _make_sched(bs=bs)
    sched.submit(Request(rid=0, prompt=np.arange(plen, dtype=np.int32),
                         max_new_tokens=max_new))
    sched.admit()
    s = sched._slots[0]
    assert s is not None
    # walk the lane to an arbitrary reachable position (plain decode)
    for _ in range(consumed):
        sched._claim_blocks(0, s.pos)
        s.pos += 1
    sched._claim_blocks(0, s.pos)       # pending-token coverage
    pre = (_alloc_snapshot(alloc), s.budget, s.n_blocks,
           s.table_row.copy().tolist(), sched._reserved_budget)
    k_eff = min(k, max_new - consumed - 1)
    if k_eff <= 0:
        return
    claimed = sched._claim_blocks(0, s.pos + k_eff)   # draft reservation
    freed = sched._trim_blocks(0, s.pos)              # full rejection
    assert freed == claimed
    post = (_alloc_snapshot(alloc), s.budget, s.n_blocks,
            s.table_row.copy().tolist(), sched._reserved_budget)
    assert post == pre


def test_full_rejection_through_the_real_verify_path():
    """consume_verify with a verify output that rejects every draft
    frees exactly the chain's claimed blocks and advances exactly one
    token (the bonus token), via the public scheduler API."""
    alloc, sched = _make_sched(bs=2)
    sched.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=20))
    sched.admit()
    s = sched._slots[0]
    sched._claim_blocks(0, s.pos)
    pre_free, pre_cached, pre_ref = _alloc_snapshot(alloc)
    pre_blocks, pos0, out0 = s.n_blocks, s.pos, len(s.out)
    # inject a draft long enough to cross block boundaries
    sched._proposers = [type("P", (), {
        "propose": staticmethod(lambda hist, k: [3] * min(k, 6))})()] * 2
    batch = sched.prepare_verify()
    assert batch is not None
    tokens, positions, counts, active = batch
    assert s.n_blocks > pre_blocks                    # chain claimed blocks
    out_tok = np.full(tokens.shape, -1, np.int32)     # model disagrees
    accept = np.zeros(tokens.shape[0], np.int32)
    sched.consume_verify(active, out_tok, accept)
    assert s.pos == pos0 + 1 and len(s.out) == out0 + 1
    # the one committed write may have crossed into the chain's first
    # claimed block; everything past it went back
    assert s.n_blocks == max((s.pos - 1) // 2 + 1, s.prompt_blocks)
    assert (_alloc_snapshot(alloc)[0]
            == pre_free - (s.n_blocks - pre_blocks))
    assert sched.accepted_tokens == 0


# ----------------------------------------------------------------------------
# serving_bench speculative smoke (the CI gate path)
# ----------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_bench_speculative_smoke(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        import serving_bench
    finally:
        sys.path.pop(0)
    rec = serving_bench.run_bench([
        "--workload", "repetitive", "--smoke", "--seed", "0",
        "--out", str(tmp_path)])
    gate = rec["speculation_gate"]
    assert gate["greedy_identical"] and gate["verify_shapes_bounded"]
    assert rec["engine_speculative"]["speculation"]["acceptance_rate"] > 0
    assert (tmp_path / "bench_smollm-135m_repetitive.json").exists()
