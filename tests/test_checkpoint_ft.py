"""Checkpoint, restart, fault-tolerance, elasticity tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.runtime.fault_tolerance import (FailureInjector, RestartableLoop,
                                           eta_budget,
                                           straggler_safe_inner_steps)
from repro.core import theory


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (32, 16)),
                       "b": jnp.zeros(16, jnp.bfloat16)},
            "step": jnp.int32(7),
            "m": [jax.random.normal(jax.random.fold_in(k, 1), (8,))]}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_latest_and_gc(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, _tree(s), keep=2)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".ckpt")]
    assert len(files) == 2
    _, step = ckpt.restore(str(tmp_path), _tree())
    assert step == 5
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_integrity_check(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    import json
    mf = json.load(open(tmp_path / "manifest.json"))
    path = tmp_path / mf["file"]
    blob = path.read_bytes()
    path.write_bytes(blob[:-4] + b"\x00\x00\x00\x00")
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), _tree())


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = _tree()
    acp.save(11, tree)
    acp.wait()
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_restartable_loop_survives_failures(tmp_path):
    """A loop with injected failures, restarted until done, produces the
    SAME final state as an uninterrupted run (exactly-once steps)."""
    def step_fn(state, step):
        return {"x": state["x"] + jnp.float32(step + 1)}

    init = {"x": jnp.float32(0.0)}
    clean = RestartableLoop(str(tmp_path / "clean"), step_fn,
                            ckpt_every=3).run(init, 17)

    inj = FailureInjector(prob=0.25, seed=42)
    loop = RestartableLoop(str(tmp_path / "faulty"), step_fn, ckpt_every=3,
                           injector=inj)
    attempts = 0
    state = None
    while attempts < 100:
        attempts += 1
        try:
            state = loop.run(init, 17)
            break
        except RuntimeError:
            continue
    assert state is not None, "never completed"
    assert attempts > 1, "no failure was injected — raise prob"
    # NOTE: steps between the last checkpoint and a crash are re-executed;
    # the step function is deterministic in (state, step) so the result is
    # identical.
    np.testing.assert_allclose(float(state["x"]), float(clean["x"]))


def test_straggler_budgets():
    spec = theory.ProblemSpec(L=1.0, beta=1.0, B=1.0, lam=0.1)
    etas = [eta_budget(spec, 64, 32, t) for t in (1, 2, 4)]
    assert etas[0] > etas[1] > etas[2] > 0
    assert straggler_safe_inner_steps(100, 0.35) == 35
    assert straggler_safe_inner_steps(100, 0.0) == 1


def test_elastic_rebalance():
    from repro.runtime.elastic import rebalance_plan
    b, T = rebalance_plan(n_old=16, n_new=8, b=128, T_remaining=10)
    assert b == 128 and T == 20     # half the machines => double the steps
    b, T = rebalance_plan(n_old=8, n_new=16, b=128, T_remaining=20)
    assert T == 10


def test_elastic_rebalance_conserves_budget():
    """T rounds UP when b*n_new does not divide the remaining budget —
    flooring would silently drop up to n_new-1 steps' worth of samples
    (4->3 machines with an odd product used to plan 6*2*3=36 < 40)."""
    from repro.runtime.elastic import rebalance_plan
    b, T = rebalance_plan(n_old=4, n_new=3, b=2, T_remaining=5)
    assert T == 7                   # ceil(40 / 6), not floor = 6
    for n_old, n_new, bb, tr in [(4, 3, 2, 5), (16, 7, 3, 11),
                                 (5, 2, 1, 1), (2, 9, 4, 13)]:
        b, T = rebalance_plan(n_old=n_old, n_new=n_new, b=bb,
                              T_remaining=tr)
        assert b * n_new * T >= bb * n_old * tr   # never fewer samples
        # and never overshoots by a full extra outer step
        assert b * n_new * (T - 1) < bb * n_old * tr


def test_train_driver_resume(tmp_path):
    """train.py --resume continues from the checkpoint (integration)."""
    from repro.launch.train import train
    d = str(tmp_path / "run")
    _, losses1 = train("smollm-135m", 4, optimizer="baseline",
                       batch_size=4, n_micro=2, seq_len=16, ckpt_dir=d,
                       log_every=100)
    _, losses2 = train("smollm-135m", 6, optimizer="baseline",
                       batch_size=4, n_micro=2, seq_len=16, ckpt_dir=d,
                       resume=True, log_every=100)
    assert len(losses2) == 2        # resumed at step 4, ran 4..5
