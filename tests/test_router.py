"""Multi-replica cluster router: placement-policy unit tests over stub
replicas, backpressure/FCFS, drain/failover requeue, stream merging,
the SchedulerStats occupancy accessor, the multi-tenant workload
generator, and the cluster bit-identity property (outputs identical
across 1 vs 2 vs 4 replicas and every policy — hypothesis-driven)."""
import types

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import lm
from repro.serving.engine import (Request, ServingEngine,
                                  multi_tenant_requests)
from repro.serving.replica import Replica, ReplicaSnapshot
from repro.serving.router import (POLICIES, Router, normalize_policy,
                                  summarize_cluster)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import SchedulerStats

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def sampled_from(*a, **k):
            return None


# ----------------------------------------------------------------------------
# policy unit tests over stub replicas (no device, no engine)
# ----------------------------------------------------------------------------

class _StubReplica:
    """Duck-typed replica: fixed occupancy + affinity probe results."""

    def __init__(self, rid, *, slots=2, queue=0, active=0, prefix=0,
                 enabled=True, cap=None):
        self.replica_id = rid
        self.enabled = enabled
        self.num_slots = slots
        self.queue_depth = queue
        self.active = active
        self.prefix = prefix
        self.submitted = []
        self.engine = types.SimpleNamespace(runner=types.SimpleNamespace(
            prefill_max_batch=slots if cap is None else cap))

    def snapshot(self):
        return ReplicaSnapshot(
            replica_id=self.replica_id, enabled=self.enabled,
            stats=SchedulerStats(
                queue_depth=self.queue_depth, active_slots=self.active,
                free_slots=self.num_slots - self.active, free_blocks=99,
                cached_blocks=0, indexed_blocks=0, reserved_blocks=0))

    def probe_prefix(self, prompt):
        return self.prefix

    def submit(self, req):
        self.submitted.append(req)
        self.queue_depth += 1

    @property
    def has_work(self):
        return bool(self.submitted)

    def take_queued(self):
        out, self.submitted, self.queue_depth = self.submitted, [], 0
        return out


def _req(rid):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=2,
                   sampling=SamplingParams(max_new_tokens=2))


def test_policy_aliases_and_validation():
    assert normalize_policy("rr") == "round-robin"
    assert normalize_policy("prefix") == "prefix-affinity"
    assert normalize_policy("least-loaded") == "least-loaded"
    for p in POLICIES:
        assert normalize_policy(p) == p
    with pytest.raises(ValueError):
        normalize_policy("random")
    with pytest.raises(ValueError):
        Router([], policy="rr")
    with pytest.raises(ValueError):
        Router([_StubReplica(0), _StubReplica(0)])
    with pytest.raises(ValueError):
        Router([_StubReplica(0)], max_queue=0)


def test_round_robin_rotates_and_skips_unavailable():
    reps = [_StubReplica(i, slots=4) for i in range(3)]
    reps[1].enabled = False
    router = Router(reps, policy="rr", max_queue=4)
    for i in range(4):
        router.submit(_req(i))
    assert router.place() == 4
    # rotation 0, (skip 1), 2, 0, 2
    assert [r.rid for r in reps[0].submitted] == [0, 2]
    assert reps[1].submitted == []
    assert [r.rid for r in reps[2].submitted] == [1, 3]
    assert router.placement_of(3) == 2 and router.placement_of(9) is None


def test_least_loaded_uses_slot_plus_queue_occupancy():
    reps = [_StubReplica(0, queue=2, active=1),
            _StubReplica(1, queue=0, active=2),
            _StubReplica(2, queue=1, active=2)]
    router = Router(reps, policy="least-loaded", max_queue=9)
    router.submit(_req(0))
    router.place()
    assert [r.rid for r in reps[1].submitted] == [0]   # load 2 < 3 <= 3
    # ties break to the lowest replica id
    reps_tie = [_StubReplica(0, queue=1), _StubReplica(1, queue=1)]
    router = Router(reps_tie, policy="least-loaded", max_queue=9)
    router.submit(_req(1))
    router.place()
    assert [r.rid for r in reps_tie[0].submitted] == [1]


def test_prefix_affinity_prefers_holder_else_least_loaded():
    reps = [_StubReplica(0, queue=0, prefix=0),
            _StubReplica(1, queue=3, prefix=8),
            _StubReplica(2, queue=1, prefix=8)]
    router = Router(reps, policy="prefix", max_queue=9)
    router.submit(_req(0))
    router.place()
    # both 1 and 2 hold 8 tokens; least-loaded tie-break picks 2
    assert [r.rid for r in reps[2].submitted] == [0]
    # nobody holds the prefix -> pure least-loaded fallback
    for r in reps:
        r.prefix = 0
    router.submit(_req(1))
    router.place()
    assert [r.rid for r in reps[0].submitted] == [1]


def test_prefix_affinity_cold_start_pinning():
    """Zero-match requests sharing a leading block chunk follow the
    router's cold-start pin (the replica where that chunk was first
    placed) instead of scattering least-loaded — the probe takes over
    once the replica actually holds blocks."""
    reps = [_StubReplica(0, slots=8), _StubReplica(1, slots=8)]
    for r in reps:
        r.engine.block_size = 2
    router = Router(reps, policy="prefix", max_queue=8)
    t1 = np.asarray([5, 6, 7, 8], np.int32)
    t2 = np.asarray([9, 9, 7, 8], np.int32)
    for rid, prompt in enumerate([t1, t2, t1, t2, t1]):
        router.submit(Request(rid=rid, prompt=prompt.copy(),
                              max_new_tokens=2,
                              sampling=SamplingParams(max_new_tokens=2)))
    router.place()
    # stub probes return 0 everywhere: tenant 1 pins to its first
    # least-loaded placement (replica 0), tenant 2 to the other, and
    # every repeat follows its pin
    assert [r.rid for r in reps[0].submitted] == [0, 2, 4]
    assert [r.rid for r in reps[1].submitted] == [1, 3]


def test_backpressure_holds_queue_fcfs():
    reps = [_StubReplica(0, slots=2, queue=2, cap=2)]
    router = Router(reps, policy="rr")
    for i in range(3):
        router.submit(_req(i))
    assert router.place() == 0            # replica at its cap
    assert router.has_work and reps[0].submitted == []
    reps[0].queue_depth = 0               # admission drained the queue
    assert router.place() == 2            # cap admits two more, in order
    assert [r.rid for r in reps[0].submitted] == [0, 1]


def test_disable_requeues_unplaced_in_order():
    reps = [_StubReplica(0, slots=4), _StubReplica(1, slots=4)]
    router = Router(reps, policy="rr", max_queue=4)
    for i in range(4):
        router.submit(_req(i))
    router.place()
    assert [r.rid for r in reps[1].submitted] == [1, 3]
    orphans = router.disable(1)
    assert [r.rid for r in orphans] == [1, 3]
    assert router.requeued == 2
    assert router.placement_of(1) is None
    router.place()                        # requeued requests go to 0
    assert [r.rid for r in reps[0].submitted] == [0, 2, 1, 3]
    assert router.placement_of(1) == 0
    router.enable(1)
    router.submit(_req(9))
    router.place()
    assert [r.rid for r in reps[1].submitted] == [9]


# ----------------------------------------------------------------------------
# real-engine cluster: identity, streaming, drain, telemetry
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


_ENGINE_KW = dict(num_slots=2, block_size=4, max_seq_len=48,
                  prefill_max_batch=2, speculate=2)


@pytest.fixture(scope="module")
def replicas4(smollm):
    params, cfg = smollm
    return [Replica(params, cfg, replica_id=i, **_ENGINE_KW)
            for i in range(4)]


@pytest.fixture(scope="module")
def single_engine(smollm):
    params, cfg = smollm
    return ServingEngine(params, cfg, **_ENGINE_KW)


def _workload(cfg, n=8, seed=0, sampling=None):
    return multi_tenant_requests(n, vocab_size=cfg.vocab_size,
                                 n_tenants=2, prefix_len=12,
                                 suffix_len=(1, 5), max_new=(2, 5),
                                 sampling=sampling, seed=seed)


def test_cluster_bit_identical_and_blocks_restored(smollm, replicas4,
                                                   single_engine):
    """Every policy, 2 replicas: cluster completions are bit-identical
    to the single-replica engine run AND to generate(); every replica's
    block pool fully restores (shared blocks may idle cached-free)."""
    params, cfg = smollm
    reqs = _workload(cfg, seed=3)
    expect = {c.rid: c.tokens for c in single_engine.run(list(reqs))}
    for policy in POLICIES:
        router = Router(replicas4[:2], policy=policy)
        done = router.run(list(reqs))
        assert len(done) == len(reqs)
        for c in done:
            np.testing.assert_array_equal(c.tokens, expect[c.rid])
        for rep in router.replicas:
            alloc = rep.engine.allocator
            assert alloc.num_free == alloc.num_blocks - 1
    r = reqs[0]
    np.testing.assert_array_equal(
        expect[r.rid],
        np.asarray(generate(params, cfg, np.asarray(r.prompt)[None],
                            r.max_new_tokens))[0])


def test_cluster_stream_merges_replica_events(smollm, replicas4):
    """stream() over 2 replicas: per-request token chunks concatenate to
    exactly the run() output, one done event per request, callbacks
    restored afterwards."""
    params, cfg = smollm
    reqs = _workload(cfg, seed=4)
    router = Router(replicas4[:2], policy="least-loaded")
    chunks = {r.rid: [] for r in reqs}
    finals = {}
    for ev in router.stream(list(reqs)):
        if ev.done:
            assert ev.rid not in finals
            finals[ev.rid] = ev.completion
        else:
            assert ev.rid not in finals
            chunks[ev.rid].extend(ev.tokens)
    assert set(finals) == {r.rid for r in reqs}
    expect = {c.rid: c.tokens for c in router.run(list(reqs))}
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(chunks[r.rid], np.int32),
                                      expect[r.rid])
    for rep in router.replicas:
        assert rep.scheduler.on_event is None


def test_cluster_drain_failover_requeues_and_completes(smollm, replicas4):
    """Disabling a replica mid-flight requeues its queued-but-unplaced
    requests onto the survivors; its admitted requests finish in place;
    every output stays bit-identical to generate()."""
    params, cfg = smollm
    reqs = _workload(cfg, n=8, seed=5)
    router = Router(replicas4[:2], policy="rr", max_queue=2)
    for rep in router.replicas:
        rep.begin_run()
    for r in reqs:
        router.submit(r)
    router.place()
    victim = router.replicas[1]
    assert victim.placed > 0
    victim.step()                         # admit (sticky) some to slots
    router.place()                        # refill the victim's queue
    queued_before = victim.snapshot().queue_depth
    assert queued_before > 0              # there IS a backlog to fail over
    active_on_victim = victim.snapshot().active_slots
    assert active_on_victim > 0           # and admitted work that stays
    orphans = router.disable(1)
    assert len(orphans) == queued_before
    assert router.requeued == len(orphans) > 0
    assert victim.snapshot().queue_depth == 0
    while router.has_work:
        router.place()
        for rep in router.replicas:
            if rep.has_work:
                rep.step()
    done, vdone = [], []
    for rep in router.replicas:
        batch = rep.take_completions()
        if rep is victim:
            vdone = batch
        done.extend(batch)
    assert len(done) == len(reqs)
    assert {c.rid for c in done} == {r.rid for r in reqs}
    for c in done:
        r = reqs[c.rid]
        np.testing.assert_array_equal(
            c.tokens,
            np.asarray(generate(params, cfg, np.asarray(r.prompt)[None],
                                r.max_new_tokens))[0])
    # the drained replica completed exactly the requests it kept (its
    # admitted slots), nothing from the failed-over backlog
    assert len(vdone) == victim.placed
    assert victim.placed <= len(reqs) - len(orphans)
    router.enable(1)


def test_run_preserves_presubmitted_requests(smollm, replicas4):
    """A request submit()ed directly to the router before run() drains
    with that run instead of being dropped — the same semantics as
    submitting to a ServingEngine ahead of run()."""
    _, cfg = smollm
    reqs = _workload(cfg, n=4, seed=8)
    router = Router(replicas4[:2], policy="least-loaded")
    router.submit(reqs[0])
    done = router.run(list(reqs[1:]))
    assert {c.rid for c in done} == {r.rid for r in reqs}


def test_all_replicas_disabled_raises(smollm, replicas4):
    _, cfg = smollm
    router = Router(replicas4[:2], policy="rr")
    router.disable(0)
    router.disable(1)
    with pytest.raises(RuntimeError):
        router.run(_workload(cfg, n=2, seed=6))
    router.enable(0)
    router.enable(1)


def test_summarize_cluster_and_snapshot_telemetry(smollm, replicas4):
    params, cfg = smollm
    reqs = _workload(cfg, n=6, seed=7)
    router = Router(replicas4[:2], policy="prefix")
    for rep in router.replicas:
        rep.reset_prefix_cache()
    done = router.run(list(reqs))
    stats = summarize_cluster(done, router.wall_time, router)
    cl = stats["cluster"]
    assert cl["policy"] == "prefix-affinity" and cl["replicas"] == 2
    assert sum(cl["placed"]) == len(reqs)
    assert cl["prompt_tokens"] == sum(len(r.prompt) for r in reqs)
    assert cl["cached_prompt_tokens"] > 0        # tenants re-hit prefixes
    assert stats["requests"] == len(reqs) and stats["tokens_per_s"] > 0
    per = cl["per_replica"]
    assert [p["replica"] for p in per] == [0, 1]
    assert all(p["warm_blocks"] >= 0 for p in per)
    snap = router.replicas[0].snapshot()
    assert snap.active_slots == 0 and snap.queue_depth == 0
    assert snap.load == 0 and snap.enabled


def test_scheduler_stats_accessor_lifecycle(smollm):
    """The structured occupancy accessor (satellite): queue/slot/block
    numbers track submit -> admit -> completion without poking scheduler
    internals."""
    params, cfg = smollm
    eng = ServingEngine(params, cfg, num_slots=2, block_size=4,
                        max_seq_len=32)
    s0 = eng.stats()
    assert s0.queue_depth == 0 and s0.active_slots == 0
    assert s0.free_slots == 2 and s0.reserved_blocks == 0
    assert s0.free_blocks == eng.allocator.num_blocks - 1
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    assert eng.stats().queue_depth == 3
    eng.scheduler.admit()
    s1 = eng.stats()
    assert s1.active_slots == 2 and s1.free_slots == 0
    assert s1.queue_depth == 1                   # third request waits
    assert s1.free_blocks < s0.free_blocks       # prompt blocks bound
    assert s1.reserved_blocks > 0                # generation budget held
    assert s1.load == 3
    eng.run([])                                  # drain the live slots
    s2 = eng.stats()
    assert s2.active_slots == 0 and s2.queue_depth == 0
    assert s2.free_blocks == s0.free_blocks and s2.reserved_blocks == 0


def test_multi_tenant_workload_generator():
    reqs = multi_tenant_requests(24, vocab_size=100, n_tenants=3,
                                 prefix_len=16, suffix_len=(2, 6),
                                 max_new=(2, 4), seed=1)
    prefixes = {r.prompt[:16].tobytes() for r in reqs}
    assert len(prefixes) == 3                    # three live tenants
    # interleaved arrivals: the first few requests span > 1 tenant
    assert len({r.prompt[:16].tobytes() for r in reqs[:4]}) > 1
    assert all(18 <= len(r.prompt) <= 22 for r in reqs)
    # per-tenant prefix lengths from a range land in different buckets
    ranged = multi_tenant_requests(12, vocab_size=100, n_tenants=4,
                                   prefix_len=(8, 32), suffix_len=2,
                                   max_new=(2, 3), seed=2)
    assert len({len(r.prompt) for r in ranged}) > 1
    # sampling stamps per-request seeds
    sampled = multi_tenant_requests(4, vocab_size=100, n_tenants=2,
                                    sampling=SamplingParams(
                                        temperature=0.8, seed=5), seed=3)
    assert [r.sampling.seed for r in sampled] == [5, 6, 7, 8]


# ----------------------------------------------------------------------------
# property: cluster outputs are bit-identical across replica counts and
# policies (the distributed form of batch-composition independence)
# ----------------------------------------------------------------------------

def test_cluster_outputs_invariant_one_two_four_replicas(smollm, replicas4,
                                                         single_engine):
    """Deterministic slice of the property below (runs even without
    hypothesis): one mixed greedy+sampled multi-tenant workload, bit-
    identical across 1 vs 2 vs 4 replicas and all three policies."""
    _, cfg = smollm
    reqs = multi_tenant_requests(5, vocab_size=cfg.vocab_size,
                                 n_tenants=2, prefix_len=(6, 14),
                                 suffix_len=(1, 4), max_new=(2, 4),
                                 sampling=SamplingParams(temperature=0.9,
                                                         top_k=4, seed=13),
                                 seed=13)
    reqs[0].sampling = SamplingParams(max_new_tokens=3)    # greedy lane
    expect = {c.rid: c.tokens for c in single_engine.run(list(reqs))}
    for policy in POLICIES:
        for count in (1, 2, 4):
            router = Router(replicas4[:count], policy=policy)
            done = router.run(list(reqs))
            assert len(done) == len(reqs), (policy, count)
            for c in done:
                np.testing.assert_array_equal(c.tokens, expect[c.rid],
                                              err_msg=f"{policy}/{count}")


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**20), n=st.integers(3, 6),
       n_tenants=st.integers(1, 3),
       policy=st.sampled_from(["rr", "least-loaded", "prefix"]),
       temperature=st.sampled_from([0.0, 0.9]))
def test_cluster_outputs_invariant_to_replica_count(smollm, replicas4,
                                                    single_engine, seed, n,
                                                    n_tenants, policy,
                                                    temperature):
    """Property (satellite): per-request outputs are bit-identical
    across 1 vs 2 vs 4 replicas and across all three policies — greedy
    and sampled lanes, with speculation enabled throughout."""
    _, cfg = smollm
    sampling = (None if temperature == 0.0 else
                SamplingParams(temperature=temperature, top_k=4,
                               seed=seed))
    reqs = multi_tenant_requests(n, vocab_size=cfg.vocab_size,
                                 n_tenants=n_tenants, prefix_len=(6, 14),
                                 suffix_len=(1, 4), max_new=(2, 4),
                                 sampling=sampling, seed=seed)
    expect = {c.rid: c.tokens for c in single_engine.run(list(reqs))}
    for count in (1, 2, 4):
        router = Router(replicas4[:count], policy=policy)
        done = router.run(list(reqs))
        assert len(done) == len(reqs)
        for c in done:
            np.testing.assert_array_equal(c.tokens, expect[c.rid])
