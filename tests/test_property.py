"""Hypothesis property-based tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import prox, theory

D = 8
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _data(seed, n=32, d=D):
    k = jax.random.PRNGKey(seed)
    X = jax.random.normal(k, (n, d)) / np.sqrt(d)
    y = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    return X, y


@given(seed=st.integers(0, 10**6), gamma=st.floats(0.05, 50.0))
def test_prox_is_firmly_nonexpansive(seed, gamma):
    """||prox(a1) - prox(a2)|| <= ||a1 - a2|| for the same subproblem."""
    X, y = _data(seed)
    k = jax.random.PRNGKey(seed + 7)
    a1 = jax.random.normal(k, (D,))
    a2 = jax.random.normal(jax.random.fold_in(k, 1), (D,))
    p1 = prox.exact_lsq_prox(a1, X, y, gamma)
    p2 = prox.exact_lsq_prox(a2, X, y, gamma)
    lhs = float(jnp.linalg.norm(p1 - p2))
    rhs = float(jnp.linalg.norm(a1 - a2))
    assert lhs <= rhs * (1 + 1e-4)


@given(seed=st.integers(0, 10**6))
def test_prox_gamma_monotone_distance(seed):
    """Larger gamma pulls the prox point closer to the anchor."""
    X, y = _data(seed)
    a = jax.random.normal(jax.random.PRNGKey(seed + 3), (D,))
    dists = []
    for gamma in [0.1, 1.0, 10.0, 100.0]:
        p = prox.exact_lsq_prox(a, X, y, gamma)
        dists.append(float(jnp.linalg.norm(p - a)))
    assert all(d1 >= d2 - 1e-5 for d1, d2 in zip(dists, dists[1:])), dists


@given(seed=st.integers(0, 10**6), gamma=st.floats(0.1, 20.0))
def test_prox_optimality_vs_random_points(seed, gamma):
    """The prox point minimizes f_t over random competitors."""
    X, y = _data(seed)
    a = jax.random.normal(jax.random.PRNGKey(seed + 5), (D,))
    p = prox.exact_lsq_prox(a, X, y, gamma)
    f_p = float(prox.prox_subproblem_value(p, a, X, y, gamma))
    for i in range(5):
        w = jax.random.normal(jax.random.PRNGKey(seed + 100 + i), (D,))
        assert f_p <= float(prox.prox_subproblem_value(w, a, X, y, gamma)) \
            + 1e-5


@given(seed=st.integers(0, 10**6), gamma=st.floats(0.1, 20.0))
def test_implicit_gradient_identity(seed, gamma):
    """Eq. (5): the prox point is the implicit-gradient fixed point."""
    X, y = _data(seed)
    a = jax.random.normal(jax.random.PRNGKey(seed + 9), (D,))
    p = prox.exact_lsq_prox(a, X, y, gamma)
    res = prox.sgd_equivalence_residual(p, a, X, y, gamma)
    assert float(jnp.linalg.norm(res)) < 1e-3 * max(1.0, float(
        jnp.linalg.norm(p)))


@given(b=st.integers(1, 4096), mult=st.integers(2, 8))
def test_rate_bound_improves_with_bT(b, mult):
    spec = theory.ProblemSpec(L=1.0, beta=1.0, B=1.0)
    r1 = theory.rate_bound_weakly_convex(spec, b, 8)
    r2 = theory.rate_bound_weakly_convex(spec, b * mult, 8)
    assert r2 < r1


@given(n=st.integers(10**3, 10**8), m=st.sampled_from([4, 16, 64]))
def test_mp_dsvrg_plan_invariants(n, m):
    spec = theory.ProblemSpec(L=1.0, beta=1.0, B=1.0)
    b = max(1, n // (m * 16))
    plan = theory.mp_dsvrg_plan(spec, n, m, b)
    assert plan.T >= 1 and plan.K >= 1 and plan.p >= 1
    assert plan.p * plan.batch <= b
    # communication decreases in b (at fixed n, m): T = n/(bm)
    plan2 = theory.mp_dsvrg_plan(spec, n, m, 2 * b)
    assert plan2.comm_rounds <= plan.comm_rounds


@given(seed=st.integers(0, 10**6), radius=st.floats(0.1, 10.0))
def test_projection_properties(seed, radius):
    w = jax.random.normal(jax.random.PRNGKey(seed), (D,)) * 5.0
    p = prox.project_l2_ball(w, radius)
    assert float(jnp.linalg.norm(p)) <= radius * (1 + 1e-5)
    # idempotent
    p2 = prox.project_l2_ball(p, radius)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p2), atol=1e-6)
