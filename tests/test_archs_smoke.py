"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one decode step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import lm

B, S = 2, 32


def _batch(cfg, key):
    kt, kv = jax.random.split(key)
    if cfg.frontend == "vision":
        s_text = S - cfg.vision_tokens
        return {
            "tokens": jax.random.randint(kt, (B, s_text), 0, cfg.vocab_size),
            "targets": jax.random.randint(kv, (B, s_text), 0,
                                          cfg.vocab_size),
            "vision_emb": jax.random.normal(kv, (B, cfg.vision_tokens,
                                                 cfg.vision_dim)),
        }
    if cfg.frontend == "audio":
        return {
            "tokens": jax.random.randint(kt, (B, S, cfg.n_codebooks), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(kv, (B, S, cfg.n_codebooks), 0,
                                          cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(kv, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, cfg, batch), has_aux=True)(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    # gradients exist, are finite, and match param shapes
    flat, _ = jax.tree.flatten(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat), arch
    pflat, _ = jax.tree.flatten(params)
    assert all(g.shape == p.shape for g, p in zip(flat, pflat))
    # one small SGD step reduces loss on the same batch (gradient sign check)
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = lm.train_loss(params2, cfg, batch)
    assert float(loss2) < float(loss) + 1e-4, (arch, float(loss),
                                               float(loss2))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_shapes(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = lm.forward(params, cfg, batch)
    if cfg.frontend == "vision":
        assert logits.shape == (B, S - cfg.vision_tokens, cfg.vocab_size)
    elif cfg.frontend == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_steps(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = lm.init_decode_state(cfg, B, max_len=64)
    tok = (jnp.zeros((B, cfg.n_codebooks), jnp.int32)
           if cfg.frontend == "audio" else jnp.zeros((B,), jnp.int32))
    step = jax.jit(lambda s, t, p: lm.decode_step(params, cfg, s, t, p))
    for pos in range(3):
        logits, state = step(state, tok, jnp.int32(pos))
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        if cfg.frontend == "audio":
            assert logits.shape == (B, cfg.n_codebooks, cfg.vocab_size)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            assert logits.shape == (B, cfg.vocab_size)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "recurrentgemma-2b"])
def test_decode_matches_prefill(arch):
    """Recurrent archs: token-by-token decode must match the parallel
    sequence form (the decode state machinery is exact)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    logits_seq, _ = lm.forward(params, cfg, batch)

    state = lm.init_decode_state(cfg, B, max_len=16)
    outs = []
    for pos in range(8):
        lg, state = lm.decode_step(params, cfg, state, toks[:, pos],
                                   jnp.int32(pos))
        outs.append(lg)
    logits_step = jnp.stack(outs, axis=1)
    assert jnp.allclose(logits_seq, logits_step, atol=2e-2), (
        arch, float(jnp.abs(logits_seq - logits_step).max()))


def test_full_configs_match_published_sizes():
    expected = {
        "rwkv6-3b": (2.5e9, 3.5e9),
        "llama4-maverick-400b-a17b": (3.5e11, 4.5e11),
        "grok-1-314b": (2.9e11, 3.4e11),
        "stablelm-3b": (2.3e9, 3.3e9),
        "smollm-135m": (1.1e8, 1.6e8),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
        "minitron-4b": (4.0e9, 5.5e9),
        "recurrentgemma-2b": (2.2e9, 3.2e9),
        "paligemma-3b": (2.0e9, 3.2e9),
        "musicgen-medium": (1.1e9, 1.7e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
