"""Observability subsystem: metrics-registry units, tracing invariants
(spans nest and never overlap per slot, monotonic timestamps on the
shared clock), counter reconciliation against Completion totals, the
bit-identity gate with tracing on, exporter schema validity, per-slot
speculative acceptance telemetry, summarize degenerate-run guards, and
bench_compare regression flagging."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import (Request, ServingEngine, summarize,
                                  synthetic_requests)
from repro.serving.observability import (DISPATCH_TID, NULL_OBS, Counter,
                                         Gauge, Histogram,
                                         MetricsRegistry, Observability,
                                         metrics_dump, to_perfetto,
                                         validate_metrics_dump,
                                         validate_trace_events)
from repro.serving.replica import Replica
from repro.serving.router import Router, summarize_cluster
from repro.serving.scheduler import Completion

pytestmark = pytest.mark.serving


# ----------------------------------------------------------------------------
# registry units (no engine needed)
# ----------------------------------------------------------------------------

def test_registry_instruments():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", replica=1)
    c.inc()
    c.inc(3)
    assert reg.counter("reqs_total", replica=1) is c     # same object
    assert reg.counter("reqs_total", replica=2) is not c
    reg.counter("reqs_total", replica=2).inc(5)
    assert reg.total("reqs_total") == 9
    g = reg.gauge("depth")
    g.set(7)
    assert reg.gauges_named("depth") == {(): 7.0}
    h = reg.histogram("lens", [0, 1, 2])
    for v in (0, 1, 1, 2, 99):
        h.observe(v)
    assert h.counts == [1, 2, 1, 1]                      # overflow bucket
    assert h.count == 5 and h.mean == pytest.approx(103 / 5)


def test_registry_reset_keeps_references():
    """Per-run reset zeroes instruments IN PLACE: references layers
    cached at construction must stay live across begin_run."""
    reg = MetricsRegistry()
    c, g = reg.counter("a"), reg.gauge("b")
    h = reg.histogram("c", [1.0])
    c.inc(4); g.set(2); h.observe(0.5)
    reg.series.append({"t": 0.0})
    reg.reset()
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    assert reg.series == []
    c.inc()
    assert reg.counter("a") is c and reg.total("a") == 1


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram([])
    with pytest.raises(ValueError):
        Histogram([2, 1])
    with pytest.raises(ValueError):
        Histogram([1, 1])


def test_null_obs_is_inert():
    obs = NULL_OBS
    assert not obs.enabled
    assert obs.scoped(3) is obs
    c = obs.counter("x")
    c.inc(10)
    assert c.value == 0
    obs.histogram("h", [1]).observe(5)
    obs.gauge("g").set(1)
    assert obs.step("decode", 0, 1) == {}
    obs.annotate_step(a=1)
    obs.begin_run()


def test_scoped_views_share_storage():
    root = Observability()
    v1, v2 = root.scoped(1), root.scoped(2)
    v1.counter("n").inc()
    v2.counter("n").inc(2)
    assert root.registry.total("n") == 3
    v1.span(0, "s", "request", 0.0, 1.0)
    v2.span(0, "s", "request", 1.0, 2.0)
    assert [s["pid"] for s in root.spans] == [1, 2]


# ----------------------------------------------------------------------------
# validators
# ----------------------------------------------------------------------------

def test_validate_trace_events_catches_malformed():
    assert validate_trace_events([]) != []
    assert validate_trace_events({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 1.0},  # no dur
        {"ph": "b", "name": "q", "pid": 0, "tid": 0, "ts": 0.0,
         "id": 7},                                     # non-string id
        {"ph": "e", "name": "q2", "pid": 0, "tid": 0, "ts": 0.0,
         "id": "9"},                                   # end w/o begin
        {"ph": "X", "name": "neg", "pid": 0, "tid": 0, "ts": -1.0,
         "dur": 1.0},                                  # negative ts
    ]}
    errs = validate_trace_events(bad)
    assert any("dur" in e for e in errs)
    assert any("string id" in e for e in errs)
    assert any("end without begin" in e for e in errs)
    assert any("non-negative ts" in e for e in errs)
    good = {"traceEvents": [
        {"ph": "X", "name": "a", "cat": "c", "pid": 0, "tid": 0,
         "ts": 0.0, "dur": 2.0},
        {"ph": "b", "name": "q", "cat": "queue", "pid": 0, "tid": 0,
         "ts": 0.0, "id": "1"},
        {"ph": "e", "name": "q", "cat": "queue", "pid": 0, "tid": 0,
         "ts": 1.0, "id": "1"},
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": "replica 0"}},
    ]}
    assert validate_trace_events(good) == []


def test_validate_metrics_dump_catches_malformed():
    assert validate_metrics_dump([]) != []
    assert validate_metrics_dump({"schema": "wrong"}) != []
    doc = {"schema": "repro.serving.metrics/v1",
           "counters": [{"name": "a", "labels": {}, "value": 1}],
           "gauges": [], "series": [{"t": 0.5}],
           "histograms": [{"name": "h", "labels": {}, "bounds": [1],
                           "counts": [0, 0], "sum": 0.0, "count": 0}]}
    assert validate_metrics_dump(doc) == []
    doc["histograms"][0]["counts"] = [0]              # wrong bucket count
    assert validate_metrics_dump(doc) != []


# ----------------------------------------------------------------------------
# end-to-end engine tracing
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _reqs(cfg, n=6, seed=3):
    return synthetic_requests(n, vocab_size=cfg.vocab_size,
                              prompt_len=(8, 20), max_new=(3, 8),
                              seed=seed)


KW = dict(num_slots=2, block_size=8, max_seq_len=64, prefill_max_batch=2,
          speculate=3)


@pytest.fixture(scope="module")
def traced_run(tiny):
    """One traced engine run shared by the invariant tests below (and an
    untraced reference run of the identical workload)."""
    params, cfg = tiny
    reqs = _reqs(cfg)
    ref = ServingEngine(params, cfg, **KW).run(list(reqs))
    obs = Observability(sample_interval=0.0)
    eng = ServingEngine(params, cfg, obs=obs, **KW)
    done = eng.run(list(reqs))
    return obs, eng, done, ref, reqs


def test_trace_on_output_bit_identical(traced_run):
    """The zero-cost contract's other half: recording must never change
    what the engine produces."""
    _, _, done, ref, _ = traced_run
    by_rid = {c.rid: c.tokens for c in ref}
    assert {c.rid for c in done} == set(by_rid)
    for c in done:
        np.testing.assert_array_equal(c.tokens, by_rid[c.rid])


def test_counters_reconcile_with_completions(traced_run):
    obs, eng, done, _, reqs = traced_run
    assert obs.registry.total("tokens_emitted_total") == sum(
        len(c.tokens) for c in done)
    assert obs.registry.total("scheduler_submitted_total") == len(reqs)
    assert obs.registry.total("scheduler_admitted_total") == len(reqs)
    assert obs.registry.total("scheduler_finished_total") == len(done)
    assert obs.registry.total("prompt_tokens_total") == sum(
        len(r.prompt) for r in reqs)
    assert obs.registry.total("spec_proposed_total") == \
        eng.scheduler.proposed_tokens
    assert obs.registry.total("spec_accepted_total") == \
        eng.scheduler.accepted_tokens
    # dispatch counters match the runner's own telemetry
    assert obs.registry.total("prefill_dispatches_total") == \
        eng.runner.prefill_dispatches
    assert obs.registry.total("verify_dispatches_total") == \
        eng.runner.verify_dispatches


def test_request_spans_cover_lifecycle(traced_run):
    """Every request gets an outer span whose prefill/decode phase
    children nest inside it, plus an async queue span."""
    obs, _, done, _, _ = traced_run
    outer = {s["args"]["rid"]: s for s in obs.spans
             if s["cat"] == "request"}
    assert set(outer) == {c.rid for c in done}
    for c in done:
        s = outer[c.rid]
        assert s["t0"] == pytest.approx(c.t_admit)
        assert s["t1"] == pytest.approx(c.t_done)
        assert s["args"]["generated"] == len(c.tokens)
        assert s["args"]["finish_reason"] == c.finish_reason
    phase = [s for s in obs.spans if s["cat"] == "phase"]
    for p in phase:
        parents = [s for s in outer.values()
                   if s["tid"] == p["tid"]
                   and s["t0"] - 1e-9 <= p["t0"]
                   and p["t1"] <= s["t1"] + 1e-9]
        assert parents, f"phase span {p} has no enclosing request span"
    qspans = {a["id"] for a in obs.asyncs}
    assert qspans == {c.rid for c in done}


def test_spans_never_overlap_per_slot(traced_run):
    """Request spans on one slot track are serialized by construction:
    a slot runs one request at a time, so spans must not overlap."""
    obs, _, _, _, _ = traced_run
    for tid in {s["tid"] for s in obs.spans if s["cat"] == "request"}:
        spans = sorted((s for s in obs.spans
                        if s["cat"] == "request" and s["tid"] == tid),
                       key=lambda s: s["t0"])
        for a, b in zip(spans, spans[1:]):
            assert a["t1"] <= b["t0"] + 1e-9, (a, b)


def test_timestamps_monotonic_and_ordered(traced_run):
    """Every span sits on one shared run clock: nonnegative, t0 <= t1,
    and dispatch steps strictly ordered (the engine is sequential)."""
    obs, _, _, _, _ = traced_run
    for s in obs.spans:
        assert 0.0 <= s["t0"] <= s["t1"]
    steps = [s for s in obs.spans if s["tid"] == DISPATCH_TID]
    assert steps, "no dispatch step records"
    for a, b in zip(steps, steps[1:]):
        assert a["t1"] <= b["t0"] + 1e-9
    ts = [row["t"] for row in obs.registry.series]
    assert ts == sorted(ts)


def test_step_records_carry_dispatch_detail(traced_run):
    obs, eng, _, _, _ = traced_run
    steps = [s for s in obs.spans if s["tid"] == DISPATCH_TID]
    kinds = {s["name"] for s in steps}
    assert "prefill" in kinds
    assert kinds <= {"prefill", "decode", "verify"}
    prefills = [s for s in steps if s["name"] == "prefill"]
    assert all("bucket" in s["args"] and "batch" in s["args"]
               for s in prefills)
    # the FIRST dispatch of each jit variant is flagged (compile
    # attribution); later dispatches of the same shape are not
    assert prefills[0]["args"]["first_dispatch"] is True
    by_bucket = {}
    for s in prefills:
        by_bucket.setdefault(tuple(s["args"]["bucket"]), []).append(s)
    for group in by_bucket.values():
        assert group[0]["args"]["first_dispatch"] is True
        assert all(not g["args"]["first_dispatch"] for g in group[1:])
    verifies = [s for s in steps if s["name"] == "verify"]
    assert all("accept_lens" in s["args"] for s in verifies)


def test_exports_valid_and_json_serializable(traced_run, tmp_path):
    obs, _, _, _, _ = traced_run
    trace = to_perfetto(obs)
    assert validate_trace_events(trace) == []
    md = metrics_dump(obs)
    assert validate_metrics_dump(md) == []
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(trace))
    assert validate_trace_events(json.loads(p.read_text())) == []
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names


def test_per_slot_acceptance_telemetry(traced_run):
    """ROADMAP item 4's signal: per-slot accept-length histograms and a
    rolling acceptance-rate gauge, recorded but not acted on."""
    obs, eng, _, _, _ = traced_run
    hists = obs.registry.histograms_named("verify_accept_len_hist")
    per_slot = {k: h for k, h in hists.items() if k}       # slot-labeled
    glob = hists.get((), None)
    if eng.scheduler.proposed_tokens == 0:
        pytest.skip("workload drafted nothing")
    assert glob is not None and glob.count > 0
    assert sum(h.count for h in per_slot.values()) == glob.count
    rates = eng.scheduler.slot_acceptance_rates()
    for i, rate in enumerate(rates):
        if rate is not None:
            assert 0.0 <= rate <= 1.0
            g = obs.registry.gauges_named("spec_accept_rate")
            assert (("slot", i),) in g


def test_cluster_trace_scopes_replicas(tiny):
    params, cfg = tiny
    reqs = _reqs(cfg, n=6, seed=5)
    ref = ServingEngine(params, cfg, **KW).run(list(reqs))
    obs = Observability(sample_interval=0.0)
    reps = [Replica(params, cfg, replica_id=i, obs=obs, **KW)
            for i in range(2)]
    router = Router(reps, policy="least-loaded", obs=obs)
    done = router.run(list(reqs))
    by_rid = {c.rid: c.tokens for c in ref}
    for c in done:
        np.testing.assert_array_equal(c.tokens, by_rid[c.rid])
    assert obs.registry.total("router_placed_total") == len(reqs)
    assert obs.registry.total("tokens_emitted_total") == sum(
        len(c.tokens) for c in done)
    trace = to_perfetto(obs)
    assert validate_trace_events(trace) == []
    pids = {e["pid"] for e in trace["traceEvents"] if e["ph"] != "M"}
    assert pids == {0, 1}
    # replica-labeled instruments, one set per replica that emitted
    emitted = {k for (n, k) in obs.registry._counters
               if n == "tokens_emitted_total"}
    assert emitted == {(), (("replica", 1),)}
    # router stamped queue + routing times onto every request
    for r in reqs:
        assert r.trace is not None and "routed" in r.trace
        assert r.trace["queued"] <= r.trace["routed"]
    sc = summarize_cluster(done, router.wall_time, router)
    assert sc["cluster"]["replicas"] == 2


# ----------------------------------------------------------------------------
# summarize degenerate-run guards
# ----------------------------------------------------------------------------

def _completion(rid=0, n=3, t_done=1.0):
    return Completion(rid=rid, prompt_len=4,
                      tokens=np.arange(n, dtype=np.int32), arrival=0.0,
                      t_admit=0.1, t_first_token=0.2, t_done=t_done,
                      cached_tokens=0, finish_reason="length")


def test_summarize_zero_wall_clock():
    stats = summarize([_completion()], 0.0)
    assert stats["tokens_per_s"] == 0.0
    assert np.isfinite(stats["ttft_p50_ms"])
    stats = summarize([], -1.0)
    assert stats["tokens_per_s"] == 0.0 and stats["requests"] == 0


def test_summarize_single_and_empty_completions():
    one = summarize([_completion(n=1)], 2.0)
    assert one["requests"] == 1
    assert one["ttft_p50_ms"] == one["ttft_p99_ms"]    # percentile collapse
    assert np.isfinite(one["tpot_p50_ms"])
    empty = summarize([], 2.0)
    assert empty == {"requests": 0, "generated_tokens": 0, "wall_s": 2.0,
                     "tokens_per_s": 0.0}


def test_summarize_cluster_degenerate(tiny):
    params, cfg = tiny
    reps = [Replica(params, cfg, replica_id=0, **KW)]
    router = Router(reps)
    stats = summarize_cluster([], 0.0, router)
    assert stats["tokens_per_s"] == 0.0
    assert stats["cluster"]["placed"] == [0]
    assert stats["cluster"]["prompt_tokens"] == 0


# ----------------------------------------------------------------------------
# bench_compare
# ----------------------------------------------------------------------------

def _bench_record(tps=100.0, p99=50.0):
    return {"arch": "a", "workload": "uniform",
            "meta": {"schema": "repro.serving.bench/v1", "git_rev": "x"},
            "engine": {"tokens_per_s": tps, "ttft_p99_ms": p99},
            "baseline": {"tokens_per_s": 10.0}, "speedup": tps / 10.0}


def test_bench_compare_flags_regressions():
    import sys
    sys.path.insert(0, "scripts")
    try:
        from bench_compare import compare
    finally:
        sys.path.pop(0)
    old = _bench_record()
    ok = compare(old, _bench_record(tps=95.0), threshold=0.10)
    assert ok["ok"] and not ok["regressions"]
    bad = compare(old, _bench_record(tps=80.0), threshold=0.10)
    assert not bad["ok"]
    assert [r["metric"] for r in bad["regressions"]] == [
        "engine.tokens_per_s", "speedup"]
    lat = compare(old, _bench_record(p99=80.0), threshold=0.10)
    assert [r["metric"] for r in lat["regressions"]] == [
        "engine.ttft_p99_ms"]           # higher latency = regression
    faster = compare(old, _bench_record(tps=150.0), threshold=0.10)
    assert faster["ok"] and faster["improvements"]
    with pytest.raises(ValueError):
        compare(old, {**_bench_record(), "workload": "mixed"})
    with pytest.raises(ValueError):
        compare(old, {**_bench_record(), "meta": {"schema": "other"}})
