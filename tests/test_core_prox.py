"""Tests for the paper's core: exact/inexact minibatch-prox (Section 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox, solvers, theory
from repro.core.losses import least_squares, loss_constants
from repro.core.minibatch_prox import run_minibatch_prox
from repro.data.synthetic import LeastSquaresStream

jax.config.update("jax_enable_x64", False)

DIM = 16


@pytest.fixture(scope="module")
def stream():
    return LeastSquaresStream(dim=DIM, noise=0.1, seed=0)


@pytest.fixture(scope="module")
def spec(stream):
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0)
    return theory.ProblemSpec(L=L, beta=beta, B=1.0, dim=DIM)


def test_exact_prox_is_fixed_point(stream):
    """Eq. (5): w_t = w_{t-1} - (1/gamma) grad phi_{I_t}(w_t)."""
    key = jax.random.PRNGKey(0)
    X, y = stream.sample(key, 64)
    w_prev = jax.random.normal(jax.random.fold_in(key, 1), (DIM,))
    for gamma in [0.1, 1.0, 10.0]:
        w_t = prox.exact_lsq_prox(w_prev, X, y, gamma)
        res = prox.sgd_equivalence_residual(w_t, w_prev, X, y, gamma)
        np.testing.assert_allclose(np.asarray(res), 0.0, atol=1e-4)


def test_exact_prox_reduces_subproblem(stream):
    key = jax.random.PRNGKey(2)
    X, y = stream.sample(key, 64)
    w_prev = jax.random.normal(jax.random.fold_in(key, 3), (DIM,))
    gamma = 1.0
    w_t = prox.exact_lsq_prox(w_prev, X, y, gamma)
    f_prev = prox.prox_subproblem_value(w_prev, w_prev, X, y, gamma)
    f_t = prox.prox_subproblem_value(w_t, w_prev, X, y, gamma)
    assert float(f_t) <= float(f_prev) + 1e-6


def test_lemma1_inequality(stream):
    """Lemma 1 with lam=0:
    ||w_t - w||^2 <= ||w_{t-1}-w||^2 - ||w_{t-1}-w_t||^2
                     - (2/gamma)(phi_I(w_t) - phi_I(w))."""
    key = jax.random.PRNGKey(4)
    X, y = stream.sample(key, 64)
    gamma = 2.0
    w_prev = jax.random.normal(jax.random.fold_in(key, 5), (DIM,))
    w_t = prox.exact_lsq_prox(w_prev, X, y, gamma)

    def phi(w):
        r = X @ w - y
        return 0.5 * jnp.mean(r * r)

    for i in range(5):
        w = jax.random.normal(jax.random.fold_in(key, 10 + i), (DIM,))
        lhs = jnp.sum((w_t - w) ** 2)
        rhs = (jnp.sum((w_prev - w) ** 2) - jnp.sum((w_prev - w_t) ** 2)
               - (2.0 / gamma) * (phi(w_t) - phi(w)))
        assert float(lhs) <= float(rhs) + 1e-4


def test_theorem4_rate(stream, spec):
    """Exact minibatch-prox achieves E[phi - phi*] <= sqrt(8) L B / sqrt(bT)."""
    for (b, T) in [(32, 32), (128, 8)]:
        res = run_minibatch_prox(stream, spec, b, T, solver="exact")
        sub = float(stream.population_suboptimality(res.w_avg))
        bound = theory.rate_bound_weakly_convex(spec, b, T)
        assert sub <= bound, (b, T, sub, bound)


def test_theorem4_b_independence(stream, spec):
    """Same bT => statistically equivalent result regardless of split."""
    subs = []
    for (b, T) in [(32, 64), (128, 16), (512, 4)]:
        res = run_minibatch_prox(stream, spec, b, T, solver="exact")
        subs.append(float(stream.population_suboptimality(res.w_avg)))
    assert max(subs) <= 3.0 * min(subs) + 1e-3, subs


def test_theorem5_strongly_convex_rate(stream):
    lam = 0.5
    X, y = stream.sample(jax.random.PRNGKey(1), 4096)
    L, beta = loss_constants(X, y, radius=1.0, lam=lam)
    spec_sc = theory.ProblemSpec(L=L, beta=beta, B=1.0, lam=lam, dim=DIM)
    b, T = 64, 16
    res = run_minibatch_prox(stream, spec_sc, b, T, solver="exact",
                             strongly_convex=True, lam=lam)
    # optimum of the ridge-regularized population objective differs from
    # w_star; compare against the regularized objective at the ridge optimum
    Xe, ye = stream.sample(jax.random.PRNGKey(10**6), 65536)
    H = Xe.T @ Xe / Xe.shape[0] + lam * jnp.eye(DIM)
    w_opt = jnp.linalg.solve(H, Xe.T @ ye / Xe.shape[0])

    def phi(w):
        r = Xe @ w - ye
        return 0.5 * jnp.mean(r * r) + 0.5 * lam * jnp.dot(w, w)

    sub = float(phi(res.w_avg) - phi(w_opt))
    bound = theory.rate_bound_strongly_convex(spec_sc, b, T)
    assert sub <= bound + 1e-5, (sub, bound)


def test_inexact_solver_matches_exact(stream, spec):
    """A GD inner solver run to convergence reproduces the exact prox path."""
    b, T = 64, 8
    exact = run_minibatch_prox(stream, spec, b, T, solver="exact", seed=3)
    inexact = run_minibatch_prox(stream, spec, b, T, solver="gd",
                                 inner_steps=400, seed=3)
    np.testing.assert_allclose(np.asarray(exact.w_avg),
                               np.asarray(inexact.w_avg), atol=5e-3)


def test_theorem7_inexact_rate(stream, spec):
    """Inexact minibatch-prox (prox-SVRG inner) still meets the Thm 7 rate."""
    b, T = 64, 16
    res = run_minibatch_prox(stream, spec, b, T, solver="prox_svrg",
                             inner_epochs=3)
    sub = float(stream.population_suboptimality(res.w_avg))
    bound = theory.rate_bound_weakly_convex(spec, b, T, exact=False)
    assert sub <= bound, (sub, bound)


def test_eta_schedules_decay(spec):
    etas_w = [theory.eta_schedule_weakly_convex(spec, 64, 32, t)
              for t in range(1, 10)]
    etas_s = [theory.eta_schedule_strongly_convex(
        theory.ProblemSpec(L=1, beta=1, B=1, lam=0.1), 64, 32, t)
        for t in range(1, 10)]
    assert all(a > b for a, b in zip(etas_w, etas_w[1:]))
    assert all(a > b for a, b in zip(etas_s, etas_s[1:]))


def test_projection():
    w = jnp.array([3.0, 4.0])
    p = prox.project_l2_ball(w, 1.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(p)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(prox.project_l2_ball(w, 10.0)),
                               np.asarray(w))


def test_solvers_agree_on_quadratic(stream):
    """All inner solvers converge to the same prox point."""
    key = jax.random.PRNGKey(7)
    X, y = stream.sample(key, 128)
    w_prev = jnp.zeros(DIM)
    gamma = 1.0
    exact = solvers.exact_quadratic(w_prev, X, y, gamma)
    loss = least_squares()

    def grad_fn(w):
        return prox.prox_subproblem_grad(w, w_prev, X, y, gamma)

    gd_sol = solvers.gd(grad_fn, w_prev, 0.2, iters=500)
    np.testing.assert_allclose(np.asarray(gd_sol), np.asarray(exact),
                               atol=1e-3)

    psvrg = solvers.prox_svrg(loss.per_example_grad, key, w_prev, X, y,
                              0.05, gamma, w_prev, epochs=8)
    np.testing.assert_allclose(np.asarray(psvrg), np.asarray(exact),
                               atol=3e-2)

    def scalar_grad(w, xv, yv):
        return jnp.dot(w, xv) - yv
    saga = solvers.saga_linear(scalar_grad, key, w_prev, X, y, 0.05, gamma,
                               w_prev, steps=8 * 128)
    np.testing.assert_allclose(np.asarray(saga), np.asarray(exact), atol=3e-2)
