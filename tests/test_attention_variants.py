"""Bisection-causal attention must match the chunked/oracle paths exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import (bisect_causal_attention,
                                    chunked_causal_attention)

K = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (2, 4, 2, 512, 32),
    (1, 8, 8, 1024, 64),
])
@pytest.mark.parametrize("depth", [1, 3])
def test_bisect_matches_chunked(B, H, KV, S, hd, depth):
    q = jax.random.normal(jax.random.fold_in(K, 1), (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(K, 2), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(K, 3), (B, S, KV, hd))
    out_c = chunked_causal_attention(q, k, v, chunk=128)
    out_b = bisect_causal_attention(q, k, v, depth=depth)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_c),
                               atol=2e-4, rtol=2e-4)


def test_bisect_matches_kernel_oracle():
    B, H, KV, S, hd = 1, 4, 2, 512, 64
    q = jax.random.normal(jax.random.fold_in(K, 4), (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(K, 5), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(K, 6), (B, S, KV, hd))
    out_b = bisect_causal_attention(q, k, v, depth=2)
    oracle = ref.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                     k.transpose(0, 2, 1, 3),
                                     v.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out_b),
                               np.asarray(oracle.transpose(0, 2, 1, 3)),
                               atol=2e-4, rtol=2e-4)


def test_bisect_reduces_flops():
    """HLO dot flops of bisect(depth=3) ~= 0.56 x chunked's S^2."""
    from repro.launch.hlo_analysis import analyze_hlo
    B, H, KV, S, hd = 1, 4, 4, 2048, 64
    q = jnp.zeros((B, S, H, hd))
    k = jnp.zeros((B, S, KV, hd))
    v = jnp.zeros((B, S, KV, hd))
    f_chunk = jax.jit(lambda q, k, v: chunked_causal_attention(
        q, k, v, chunk=256)).lower(q, k, v).compile()
    f_bisect = jax.jit(lambda q, k, v: bisect_causal_attention(
        q, k, v, depth=3)).lower(q, k, v).compile()
    fl_c = analyze_hlo(f_chunk.as_text())["dot_flops"]
    fl_b = analyze_hlo(f_bisect.as_text())["dot_flops"]
    assert fl_b < 0.66 * fl_c, (fl_b, fl_c, fl_b / fl_c)


def test_train_loss_same_under_bisect():
    import dataclasses
    from repro.configs import get_config
    from repro.models import lm
    cfg = get_config("smollm-135m").reduced(attn_chunk=64)
    # bisect needs S >= 512: use a longer tiny batch
    B, S = 1, 512
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                           cfg.vocab_size)}
    l1, _ = lm.train_loss(params, cfg, batch)
    cfg2 = dataclasses.replace(cfg, attn_impl="bisect")
    l2, _ = lm.train_loss(params, cfg2, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
