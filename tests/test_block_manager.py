"""Refcounted BlockAllocator: property tests (hypothesis) for
alloc/free/share/copy-on-write invariants, plus deterministic unit tests
of the prefix index (chain match, divergent-block match, LRU eviction).

Invariants under random churn:
  * refcounts never negative (decref of a dead block raises),
  * no double free, no partial grants,
  * conservation: num_free + live blocks == num_blocks - 1,
  * shared (refcount > 1) or indexed blocks are never writable in place,
  * a prefix match only ever returns blocks whose registered content
    equals the prompt's corresponding chunk.
"""
import numpy as np
import pytest

from repro.serving.block_manager import NULL_BLOCK, BlockAllocator

pytestmark = pytest.mark.serving

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # property tests degrade gracefully
    HAVE_HYPOTHESIS = False

    def given(*a, **k):               # keep decorators importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class st:                         # noqa: N801 — stand-in namespace
        @staticmethod
        def lists(*a, **k):
            return None

        @staticmethod
        def integers(*a, **k):
            return None


# ----------------------------------------------------------------------------
# deterministic unit tests
# ----------------------------------------------------------------------------

def test_basic_refcounting():
    alloc = BlockAllocator(8)
    blocks = alloc.alloc(3)
    assert len(blocks) == 3 and NULL_BLOCK not in blocks
    assert all(alloc.refcount(b) == 1 for b in blocks)
    assert all(alloc.is_writable(b) for b in blocks)
    alloc.incref(blocks[0])
    assert alloc.refcount(blocks[0]) == 2
    assert not alloc.is_writable(blocks[0])     # shared -> copy-on-write
    alloc.decref(blocks[0])
    assert alloc.is_writable(blocks[0])
    alloc.free(blocks)
    assert alloc.num_free == 7
    with pytest.raises(ValueError):
        alloc.free([blocks[0]])                 # double free
    with pytest.raises(ValueError):
        alloc.decref(NULL_BLOCK)                # reserved null block
    with pytest.raises(ValueError):
        alloc.incref(blocks[1])                 # free block: not shareable


def test_alloc_exhaustion_no_partial_grant():
    alloc = BlockAllocator(6)
    got = alloc.alloc(5)
    assert got is not None and alloc.num_free == 0
    assert alloc.alloc(1) is None
    alloc.free(got[:2])
    assert alloc.alloc(3) is None               # still short: nothing taken
    assert alloc.num_free == 2


def test_prefix_chain_match_and_partial_divergence():
    bs = 4
    alloc = BlockAllocator(32, block_size=bs)
    prompt = np.arange(11, dtype=np.int32)      # 2 full blocks + 3 tail
    blocks = alloc.alloc(3)
    assert alloc.match_prefix(prompt).tokens(bs) == 0
    alloc.register_prefix(prompt, blocks)       # publishes blocks 0,1 only
    # identical prompt: both full blocks hit; the tail block was partial
    # (never registered), so nothing more matches
    m = alloc.match_prefix(prompt)
    assert m.full_blocks == blocks[:2] and m.partial_block is None
    assert m.tokens(bs) == 8
    # a prompt diverging inside block 1 matches block 0 fully and block 1
    # partially — the first divergent block, shareable with COW
    div = prompt.copy()
    div[6] = 99
    m = alloc.match_prefix(div)
    assert m.full_blocks == blocks[:1]
    assert m.partial_block == blocks[1] and m.partial_len == 2
    assert m.tokens(bs) == 6
    # chain hashing: same chunk content under a different prefix must NOT
    # match (block identity includes everything before it)
    shifted = np.concatenate([[77], prompt[:10]]).astype(np.int32)
    assert alloc.match_prefix(shifted).tokens(bs) == 0


def test_cached_free_revival_and_lru_eviction():
    bs = 2
    alloc = BlockAllocator(4, block_size=bs)    # 3 usable blocks
    prompt = np.array([1, 2, 3, 4], np.int32)
    blocks = alloc.alloc(2)
    alloc.register_prefix(prompt, blocks)
    alloc.free(blocks)                          # -> cached-free, still match
    assert alloc.num_free == 3 and alloc.num_cached == 2
    m = alloc.match_prefix(prompt)
    assert m.full_blocks == blocks
    alloc.share(m)                              # revival: refcount 0 -> 1
    assert alloc.refcount(blocks[0]) == 1
    assert not alloc.is_writable(blocks[0])     # still published
    alloc.unshare(m)
    # allocation pressure evicts the LRU chain root; its indexed
    # descendant is unreachable once the chain breaks, so the cascade
    # unregisters and frees it in the same eviction
    taken = alloc.alloc(3)
    assert taken is not None and alloc.cache_evictions == 1
    assert alloc.num_cached == 0
    assert alloc.match_prefix(prompt).tokens(bs) == 0
    alloc.free(taken)


def test_reset_prefix_cache():
    alloc = BlockAllocator(8, block_size=2)
    prompt = np.array([5, 6, 7, 8], np.int32)
    blocks = alloc.alloc(2)
    alloc.register_prefix(prompt, blocks)
    alloc.free(blocks)
    assert alloc.num_cached == 2
    alloc.reset_prefix_cache()
    assert alloc.num_cached == 0 and alloc.num_free == 7
    assert alloc.match_prefix(prompt).tokens(2) == 0


# ----------------------------------------------------------------------------
# property tests: random admit/share/write/evict churn
# ----------------------------------------------------------------------------

N_BLOCKS = 24


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=120))
def test_refcount_invariants_under_churn(seeds):
    alloc = BlockAllocator(N_BLOCKS)
    refs = {}                                   # block -> model refcount
    handles = []                                # each: list of held blocks
    for s in seeds:
        op = s % 3
        if op == 0:                             # admit: alloc 0..4 blocks
            got = alloc.alloc(s // 4 % 5)
            if got is not None:
                for b in got:
                    refs[b] = refs.get(b, 0) + 1
                handles.append(got)
        elif op == 1 and handles:               # share one handle's blocks
            h = handles[s // 4 % len(handles)]
            for b in h:
                alloc.incref(b)
                refs[b] += 1
            handles.append(list(h))
        elif op == 2 and handles:               # finish: drop one handle
            h = handles.pop(s // 4 % len(handles))
            alloc.free(h)
            for b in h:
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
        # invariants
        assert all(v > 0 for v in refs.values())
        assert all(alloc.refcount(b) == v for b, v in refs.items())
        assert alloc.num_free + len(refs) == N_BLOCKS - 1  # conservation
        for b, v in refs.items():
            assert alloc.is_writable(b) == (v == 1)
    for h in handles:                           # drain: everything returns
        alloc.free(h)
    assert alloc.num_free == N_BLOCKS - 1
    with pytest.raises(ValueError):
        alloc.decref(1)                         # refcounts never negative


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=80))
def test_prefix_share_cow_invariants(seeds):
    """Engine-shaped churn: admit prompts with shared prefixes through
    match/share/alloc/register, simulate decode writes with the COW rule,
    and check that matches only ever return content-correct blocks and
    that shared blocks are never written in place."""
    bs = 4
    alloc = BlockAllocator(N_BLOCKS, block_size=bs)
    rng_prompts = [np.array(p, np.int32) for p in (
        [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12],  # base: 3 full blocks
        [1, 2, 3, 4, 5, 6, 7, 8, 9, 10],  # strict prefix, ends mid-block
        [1, 2, 3, 4, 5, 6, 9],             # diverges inside block 1 (d=2)
        [1, 2, 3, 4],                      # exact one block
        [7, 7, 7, 7, 7],                   # unrelated
    )]
    live = []           # (blocks_held, prompt)
    content = {}        # block -> token chunk it holds (model of device KV)
    for s in seeds:
        op = s % 2
        if op == 0:                              # admit
            prompt = rng_prompts[s // 2 % len(rng_prompts)]
            m = alloc.match_prefix(prompt)
            # every matched block's registered content must equal the
            # prompt's corresponding chunk (content-correct sharing)
            for j, b in enumerate(m.full_blocks):
                np.testing.assert_array_equal(
                    content[b], prompt[j * bs:(j + 1) * bs])
            if m.partial_block is not None:
                f = len(m.full_blocks)
                np.testing.assert_array_equal(
                    content[m.partial_block][:m.partial_len],
                    prompt[f * bs:f * bs + m.partial_len])
            total = -(-(len(prompt) + 2) // bs)  # +2 generated tokens
            alloc.share(m)
            fresh = alloc.alloc(total - len(m.full_blocks))
            if fresh is None:
                alloc.unshare(m)
                continue
            blocks = list(m.full_blocks)
            rest = fresh
            if m.partial_block is not None:
                if m.partial_len == len(prompt) - len(blocks) * bs:
                    blocks.append(m.partial_block)   # lazy COW later
                else:                                 # eager COW now
                    assert not alloc.is_writable(m.partial_block)
                    content[fresh[0]] = content[m.partial_block].copy()
                    alloc.decref(m.partial_block)
                    blocks.append(fresh[0])
                    rest = fresh[1:]
            blocks += rest
            # "prefill": write prompt chunks into writable blocks only
            nfull = len(prompt) // bs
            for j in range(nfull + (1 if len(prompt) % bs else 0)):
                b = blocks[j]
                chunk = prompt[j * bs:(j + 1) * bs]
                if alloc.is_writable(b):
                    content[b] = np.array(chunk, np.int32)
                else:       # shared: content must already be there
                    np.testing.assert_array_equal(
                        content[b][:len(chunk)], chunk)
            alloc.register_prefix(prompt, blocks)
            # "decode": first generated token writes block len(prompt)//bs
            j = len(prompt) // bs
            if j < len(blocks) and not alloc.is_writable(blocks[j]):
                # lazy COW: swap in the reserved private copy (it leaves
                # the table-order list so refs stay one-per-block)
                repl = blocks.pop()
                assert alloc.is_writable(repl)
                content[repl] = content[blocks[j]].copy()
                alloc.decref(blocks[j])
                blocks[j] = repl
            live.append(blocks)
        elif live:                               # finish a sequence
            alloc.free(live.pop(s // 2 % len(live)))
    for blocks in live:
        alloc.free(blocks)
    assert alloc.num_free == N_BLOCKS - 1
